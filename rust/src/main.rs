//! `xr-edge-dse` CLI — the launcher over the DSE library and the serving
//! coordinator.
//!
//! ```text
//! xr-edge-dse map     --arch simba --net detnet          # mapper report
//! xr-edge-dse energy  --arch simba --net detnet --node 7 --flavor p1
//! xr-edge-dse area    --node 7                           # Table 2
//! xr-edge-dse ips     --node 7                           # Table 3
//! xr-edge-dse edp                                        # Fig 2(f)
//! xr-edge-dse fig3d                                      # Fig 3(d)
//! xr-edge-dse pareto  --node 7 --ips 10                  # undominated designs
//! xr-edge-dse hybrid  --arch simba --net detnet --ips 10 # NVM/SRAM lattice
//! xr-edge-dse search  --node 7 --ips 10 --budget 400     # guided DSE
//! xr-edge-dse sweep   --out artifacts/figures            # all CSV series
//! xr-edge-dse serve   --model detnet --fps 10 --seconds 5  # PJRT serving
//! xr-edge-dse scenario --preset paper                # multi-stream serving
//! xr-edge-dse fleet   --devices 8 --streams 64       # fleet placement sim
//! xr-edge-dse obs     artifacts/trace.json           # summarize a run journal
//! xr-edge-dse run manifests/scenario_paper.xrdse     # run a .xrdse manifest
//! xr-edge-dse run manifests/search_7nm.xrdse --set budget=100
//! xr-edge-dse manifest check manifests/*.xrdse       # validate + resolved dump
//! ```
//!
//! Every command takes `--trace <path>` / `--metrics <path>` to write a
//! Perfetto-loadable Chrome trace (plus a JSONL journal sibling) and the
//! deterministic metrics snapshot; `obs` reads either back.
//!
//! Every analytical command is a [`Query`] over the unified evaluation
//! engine (`xr_edge_dse::eval`): the command picks its axes (archs × nets
//! × nodes × MRAM devices × assignments — named flavors or the full hybrid
//! lattice), chains stages (vs-SRAM baseline, feasibility, Pareto, top-k)
//! and renders through a table/CSV sink. Grids are sharded across threads
//! (override with `XR_DSE_THREADS`, 1 = sequential) with deterministic
//! output ordering.

use xr_edge_dse::arch::{self, MemFlavor, PeConfig};
use xr_edge_dse::eval::{Assignments, DesignPoint, Devices, Engine, Query};
use xr_edge_dse::report::{pct, sci, Csv, Table};
use xr_edge_dse::tech::{paper_mram_for, Device, Node};
use xr_edge_dse::util::cli::{parse, usage, OptSpec};
use xr_edge_dse::{dse, power, workload};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "arch", takes_value: true, help: "cpu|eyeriss|simba[_v1]", default: Some("simba") },
        OptSpec { name: "net", takes_value: true, help: "detnet|edsnet|tiny_cnn", default: Some("detnet") },
        OptSpec { name: "node", takes_value: true, help: "tech node nm (45|40|28|22|7)", default: Some("7") },
        OptSpec { name: "flavor", takes_value: true, help: "sram|p0|p1", default: Some("sram") },
        OptSpec { name: "device", takes_value: true, help: "stt|sot|vgsot (default: paper pick per node)", default: None },
        OptSpec { name: "ips", takes_value: true, help: "inference rate for power eval", default: Some("10") },
        OptSpec { name: "model", takes_value: true, help: "artifact model name for serve", default: Some("detnet") },
        OptSpec { name: "fps", takes_value: true, help: "sensor frame rate for serve", default: Some("10") },
        OptSpec { name: "seconds", takes_value: true, help: "serve duration", default: Some("5") },
        OptSpec { name: "artifacts", takes_value: true, help: "artifacts directory", default: Some("artifacts") },
        OptSpec { name: "out", takes_value: true, help: "output dir for sweep CSVs", default: Some("artifacts/figures") },
        OptSpec { name: "preset", takes_value: true, help: "scenario preset: paper|hand|stress", default: Some("paper") },
        OptSpec { name: "backend", takes_value: true, help: "scenario backend: auto|pjrt|synthetic", default: Some("auto") },
        OptSpec { name: "horizon", takes_value: true, help: "scenario: modeled seconds (default: preset's)", default: None },
        OptSpec { name: "time-scale", takes_value: true, help: "scenario: wall-clock compression (default: preset's)", default: None },
        OptSpec { name: "csv", takes_value: true, help: "scenario/search: write CSV to this path", default: None },
        OptSpec { name: "strategy", takes_value: true, help: "search: exhaustive|random|hill|anneal|all", default: Some("all") },
        OptSpec { name: "budget", takes_value: true, help: "search: max candidate evaluations", default: Some("400") },
        OptSpec { name: "seed", takes_value: true, help: "search: PRNG seed (deterministic replay)", default: Some("42") },
        OptSpec { name: "batch", takes_value: true, help: "search: candidates evaluated in parallel per round", default: Some("64") },
        OptSpec { name: "objective", takes_value: true, help: "search: energy|area|edp", default: Some("energy") },
        OptSpec { name: "max-area", takes_value: true, help: "search: die-area budget, mm²", default: None },
        OptSpec { name: "max-power", takes_value: true, help: "search: P_mem budget at --ips, µW", default: None },
        OptSpec { name: "precision", takes_value: true, help: "workload precision policy: int8|int4|fp16|w<N>a<M>", default: Some("int8") },
        OptSpec { name: "mixed-precision", takes_value: false, help: "search: add INT4/INT8/FP16 bit-width knob axes", default: None },
        OptSpec { name: "runner", takes_value: true, help: "scenario: virtual|threads replay engine", default: Some("virtual") },
        OptSpec { name: "devices", takes_value: true, help: "fleet: device count", default: Some("8") },
        OptSpec { name: "streams", takes_value: true, help: "fleet: total stream count", default: Some("64") },
        OptSpec { name: "policy", takes_value: true, help: "fleet: round-robin|weighted|least-loaded", default: Some("least-loaded") },
        OptSpec { name: "min-ips", takes_value: true, help: "fleet: per-stream sustained-IPS deployment constraint", default: None },
        OptSpec { name: "from-search", takes_value: false, help: "fleet: deploy a search frontier instead of the paper palette", default: None },
        OptSpec { name: "set", takes_value: true, help: "manifest override: key=value with dotted paths (repeatable)", default: None },
        OptSpec { name: "trace", takes_value: true, help: "write Chrome trace_events JSON (+ .jsonl journal) here", default: None },
        OptSpec { name: "metrics", takes_value: true, help: "write the metrics snapshot JSON here (obs: read it)", default: None },
        OptSpec { name: "verbose", takes_value: false, help: "per-layer detail", default: None },
    ]
}

fn flavor_of(s: &str) -> anyhow::Result<MemFlavor> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "sram" | "sram-only" => MemFlavor::SramOnly,
        "p0" => MemFlavor::P0,
        "p1" => MemFlavor::P1,
        other => anyhow::bail!("unknown flavor '{other}'"),
    })
}

/// The `--precision` policy (INT8 identity by default).
fn precision_of(args: &xr_edge_dse::util::cli::Args) -> anyhow::Result<workload::PrecisionPolicy> {
    workload::PrecisionPolicy::from_str(args.get("precision").unwrap())
}

/// Engine over one named (arch, net) pair at the `--precision` policy.
fn pair_engine(args: &xr_edge_dse::util::cli::Args) -> anyhow::Result<Engine> {
    let a = arch::by_name(args.get("arch").unwrap())?;
    let net = workload::builtin::by_name(args.get("net").unwrap())?
        .with_precision(precision_of(args)?);
    Ok(Engine::new(vec![a], vec![net]))
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let args = parse(&argv[1..], &specs())?;
    // `obs` *reads* journal/metrics files; every other command may record
    // and flush them (declaring a path turns the global journal on).
    if cmd != "obs" {
        xr_edge_dse::obs::set_output_paths(
            args.get("trace").map(std::path::PathBuf::from),
            args.get("metrics").map(std::path::PathBuf::from),
        );
    }
    let node = Node::from_nm(args.get_usize("node")?.unwrap_or(7))?;
    let mram = match args.get("device") {
        Some(d) => Device::from_str(d)?,
        None => paper_mram_for(node),
    };

    match cmd.as_str() {
        "map" => {
            let engine = pair_engine(&args)?;
            let entry = &engine.entries()[0];
            let (a, map) = (&entry.arch, &entry.map);
            let mut t = Table::new(
                &format!("mapping {} on {}", map.network, a.name),
                &["layer", "macs", "cycles", "bw-bound", "util"],
            );
            for lm in &map.per_layer {
                if !args.flag("verbose") && lm.macs == 0.0 {
                    continue;
                }
                t.row(vec![
                    lm.layer.clone(),
                    sci(lm.macs),
                    sci(lm.cycles()),
                    if lm.bandwidth_cycles > lm.compute_cycles { "yes" } else { "no" }.into(),
                    format!("{:.3}", lm.macs / (lm.cycles() * a.total_macs() as f64).max(1.0)),
                ]);
            }
            print!("{}", t.render());
            println!(
                "total: {} MACs, {} cycles, avg util {:.3}",
                sci(map.total_macs()),
                sci(map.total_cycles()),
                map.utilization(a)
            );
        }
        "energy" => {
            let flavor = flavor_of(args.get("flavor").unwrap())?;
            let engine = pair_engine(&args)?;
            let p = Query::over(&engine)
                .nodes(&[node])
                .devices(Devices::Fixed(mram))
                .assignments(Assignments::Flavors(vec![flavor]))
                .points()
                .pop()
                .expect("single-point query");
            let b = &p.energy;
            let mut t = Table::new(
                &format!(
                    "energy {} [{}] on {} @{} {} ({})",
                    p.network,
                    p.precision,
                    p.arch,
                    node.label(),
                    flavor.label(),
                    mram.label()
                ),
                &["component", "read (µJ)", "write (µJ)", "total (µJ)"],
            );
            let uj = 1e-6;
            t.row(vec!["compute".into(), "-".into(), "-".into(), format!("{:.3}", b.compute_pj * uj)]);
            for l in &b.levels {
                t.row(vec![
                    format!("{} [{}]", l.level, l.device.label()),
                    format!("{:.3}", l.read_pj * uj),
                    format!("{:.3}", l.write_pj * uj),
                    format!("{:.3}", (l.read_pj + l.write_pj) * uj),
                ]);
            }
            t.row(vec!["TOTAL".into(), format!("{:.3}", b.mem_read_pj() * uj), format!("{:.3}", b.mem_write_pj() * uj), format!("{:.3}", b.total_pj() * uj)]);
            print!("{}", t.render());
            println!("latency: {:.3} ms   EDP: {}", p.latency_ns / 1e6, sci(p.edp()));
        }
        "area" => {
            // Table 2 as a query: flavor axis with a vs-SRAM baseline; the
            // savings columns come from the baseline stage. Area is
            // workload-independent, so the engine carries the cheapest
            // builtin net purely to satisfy the (arch × net) pairing.
            let engine = Engine::new(
                vec![arch::simba(PeConfig::V2), arch::eyeriss(PeConfig::V2)],
                vec![workload::builtin::tiny_cnn()],
            );
            let rows = Query::over(&engine)
                .nodes(&[node])
                .devices(Devices::Fixed(mram))
                .baseline(|p| p.flavor() == Some(MemFlavor::SramOnly))
                .collect();
            let mut t = Table::new(
                &format!("Table 2 — area at {} ({})", node.label(), mram.label()),
                &["architecture", "SRAM-only (mm²)", "P0 (mm²)", "P1 (mm²)", "P0 saving", "P1 saving"],
            );
            for group in rows.chunks(MemFlavor::ALL.len()) {
                let (base, p0, p1) = (&group[0], &group[1], &group[2]);
                t.row(vec![
                    base.point.arch.clone(),
                    format!("{:.2}", base.point.area_mm2),
                    format!("{:.2}", p0.point.area_mm2),
                    format!("{:.2}", p1.point.area_mm2),
                    pct(p0.area_saving().expect("baseline attached")),
                    pct(p1.area_saving().expect("baseline attached")),
                ]);
            }
            print!("{}", t.render());
        }
        "ips" => {
            let rows = power::table3(
                &[
                    (workload::builtin::by_name("detnet")?, 10.0),
                    (workload::builtin::by_name("edsnet")?, 0.1),
                ],
                &[arch::simba(PeConfig::V2), arch::eyeriss(PeConfig::V2)],
                node,
                mram,
            );
            let mut t = Table::new(
                &format!("Table 3 — IPS analysis @{} v2 (64×64)", node.label()),
                &["workload", "arch", "IPS_min", "lat P0 (ms)", "lat P1 (ms)", "P_mem save P0", "P_mem save P1"],
            );
            for r in rows {
                t.row(vec![
                    r.workload,
                    r.arch,
                    format!("{}", r.ips_min),
                    format!("{:.2}", r.latency_p0_ms),
                    format!("{:.2}", r.latency_p1_ms),
                    pct(r.savings_p0),
                    pct(r.savings_p1),
                ]);
            }
            print!("{}", t.render());
        }
        "edp" => {
            let s = dse::paper_sweeper()?;
            let t = Query::over(s.engine())
                .nodes(&Node::ALL)
                .assignments(Assignments::Flavors(vec![MemFlavor::SramOnly]))
                .to_table(
                    "Fig 2(f) — EDP vs node (SRAM-only)",
                    &["arch", "net", "node", "energy (µJ)", "latency (ms)", "EDP (µJ·ms)"],
                    |row| {
                        let p = &row.point;
                        vec![
                            p.arch.clone(),
                            p.network.clone(),
                            p.node.label(),
                            format!("{:.2}", p.energy.total_pj() * 1e-6),
                            format!("{:.3}", p.latency_ns / 1e6),
                            format!("{:.3}", p.energy.total_pj() * 1e-6 * p.latency_ns / 1e6),
                        ]
                    },
                );
            print!("{}", t.render());
        }
        "fig3d" => {
            // vs-SRAM deltas via the baseline stage — one group-local
            // lookup instead of the old O(n²) scan over the grid.
            let s = dse::paper_sweeper()?;
            let t = Query::over(s.engine())
                .nodes(&[Node::N28, Node::N7])
                .baseline(|p| p.flavor() == Some(MemFlavor::SramOnly))
                .to_table(
                    "Fig 3(d) — single-inference energy, 9 variants × 2 nodes",
                    &["net", "node", "arch", "flavor", "total (µJ)", "vs SRAM"],
                    |row| {
                        let p = &row.point;
                        vec![
                            p.network.clone(),
                            p.node.label(),
                            p.arch.clone(),
                            p.flavor_label().into(),
                            format!("{:.2}", p.energy.total_pj() * 1e-6),
                            pct(row.energy_vs_baseline().expect("SRAM baseline present")),
                        ]
                    },
                );
            print!("{}", t.render());
        }
        "hybrid" => {
            // §5's concluding suggestion, executable: the hybrid lattice is
            // a first-class assignment axis; rank every NVM/SRAM split by
            // memory power at --ips through the top-k stage.
            let ips = args.get_f64("ips")?.unwrap_or(10.0);
            let engine = pair_engine(&args)?;
            let a = engine.entries()[0].arch.clone();
            let net_name = engine.entries()[0].map.network.clone();
            let top = Query::over(&engine)
                .nodes(&[node])
                .devices(Devices::Fixed(mram))
                .assignments(Assignments::Lattice)
                .top_k(move |p| p.p_mem_uw(ips), 8)
                .points();
            let mut t = Table::new(
                &format!("hybrid NVM/SRAM splits — {} on {} @{} {} IPS (best first)",
                    net_name, a.name, node.label(), ips),
                &["MRAM levels", "P_mem (µW)", "E_mem/inf (µJ)", "retention (µW)", "area (mm²)"],
            );
            for p in &top {
                let levels = p.assignment.mram_level_names(&a);
                t.row(vec![
                    if levels.is_empty() { "(none — SRAM-only)".into() } else { levels.join("+") },
                    format!("{:.2}", p.p_mem_uw(ips)),
                    format!("{:.3}", p.power.e_mem_inf_pj * 1e-6),
                    format!("{:.2}", p.power.p_retention_uw),
                    format!("{:.2}", p.area_mm2),
                ]);
            }
            print!("{}", t.render());
            let named = Query::over(&engine)
                .nodes(&[node])
                .devices(Devices::Fixed(mram))
                .assignments(Assignments::Flavors(vec![MemFlavor::P0, MemFlavor::P1]))
                .points();
            println!("named flavors: P0 {:.2} µW, P1 {:.2} µW, best split {:.2} µW",
                named[0].p_mem_uw(ips), named[1].p_mem_uw(ips), top[0].p_mem_uw(ips));
        }
        "pareto" => {
            // Which (arch × flavor) variants at --node are undominated in
            // (P_mem @ --ips, area, latency)? Query-evaluated grid +
            // pareto::frontier, the §5 decision procedure as a command.
            let ips = args.get_f64("ips")?.unwrap_or(10.0);
            let net = workload::builtin::by_name(args.get("net").unwrap())?
                .with_precision(precision_of(&args)?);
            let net_name = net.name.clone();
            let engine = Engine::new(
                vec![arch::cpu(), arch::eyeriss(PeConfig::V2), arch::simba(PeConfig::V2)],
                vec![net],
            );
            let pts = Query::over(&engine)
                .nodes(&[node])
                .devices(Devices::Fixed(mram))
                .points();
            let feasible = dse::pareto::feasible(&pts, ips);
            let front = dse::pareto::frontier(&pts, ips);
            let mut t = Table::new(
                &format!(
                    "Pareto frontier — {} @{} {} IPS (query grid, {} points)",
                    net_name,
                    node.label(),
                    ips,
                    pts.len()
                ),
                &["arch", "flavor", "P_mem (µW)", "area (mm²)", "latency (ms)", "feasible", "frontier"],
            );
            for (i, p) in pts.iter().enumerate() {
                let o = dse::pareto::objectives(p, ips);
                t.row(vec![
                    p.arch.clone(),
                    p.flavor_label().into(),
                    format!("{:.2}", o.p_mem_uw),
                    format!("{:.2}", o.area_mm2),
                    format!("{:.3}", o.latency_ms),
                    if feasible.contains(&i) { "yes" } else { "NO" }.into(),
                    if front.contains(&i) { "★" } else { "" }.into(),
                ]);
            }
            print!("{}", t.render());
        }
        "search" => {
            // Guided design-space search over the parameterized space:
            // the paper grid is a set of named points inside it; the
            // strategies look for better designs under hard constraints.
            // Flags translate into the same ExperimentSpec a manifest
            // binds to and execute through the manifest layer.
            let spec = xr_edge_dse::manifest::flags::search_spec(&args, node, mram)?;
            xr_edge_dse::manifest::run(&spec)?;
        }
        "sweep" => {
            let out = std::path::PathBuf::from(args.get("out").unwrap());
            let n = write_figure_csvs(&out)?;
            println!("wrote {n} CSV series to {}", out.display());
        }
        "serve" => {
            serve(&args)?;
        }
        "scenario" => {
            let spec = xr_edge_dse::manifest::flags::scenario_spec(&args, node, mram)?;
            xr_edge_dse::manifest::run(&spec)?;
        }
        "fleet" => {
            let spec = xr_edge_dse::manifest::flags::fleet_spec(&args, node, mram)?;
            xr_edge_dse::manifest::run(&spec)?;
        }
        "run" => {
            run_manifest(&args)?;
        }
        "manifest" => {
            manifest_cmd(&args)?;
        }
        "obs" => {
            obs_cmd(&args)?;
        }
        "help" | "--help" | "-h" => print_help(),
        other => {
            print_help();
            anyhow::bail!("unknown command '{other}'");
        }
    }
    xr_edge_dse::obs::write_if_requested()?;
    Ok(())
}

/// `obs`: summarize a run journal written by `--trace` / `XR_DSE_TRACE`
/// (Chrome `trace_events` JSON or the JSONL sibling — detected by
/// content): top spans by self time, per-clock event counts, and cache
/// hit rates when a `--metrics` snapshot JSON is also given.
fn obs_cmd(args: &xr_edge_dse::util::cli::Args) -> anyhow::Result<()> {
    use std::collections::BTreeMap;
    use xr_edge_dse::obs::{parse_events, span_totals};
    let Some(path) = args.positional.first() else {
        anyhow::bail!("usage: xr-edge-dse obs <trace.json|journal.jsonl> [--metrics snapshot.json]");
    };
    let events = parse_events(&std::fs::read_to_string(path)?)?;
    anyhow::ensure!(!events.is_empty(), "no events in {path}");

    let mut t = Table::new(
        &format!("top spans by self time — {path} ({} events)", events.len()),
        &["span", "count", "total (ms)", "self (ms)"],
    );
    for s in span_totals(&events).iter().take(12) {
        t.row(vec![
            s.name.clone(),
            s.count.to_string(),
            format!("{:.3}", s.total_s * 1e3),
            format!("{:.3}", s.self_s * 1e3),
        ]);
    }
    print!("{}", t.render());

    let mut by_clock: BTreeMap<&str, u64> = BTreeMap::new();
    for e in &events {
        *by_clock.entry(e.clock.as_str()).or_default() += 1;
    }
    let clocks: Vec<String> =
        by_clock.iter().map(|(c, n)| format!("{c} {n}")).collect();
    println!("events by clock: {}", clocks.join(", "));

    if let Some(mpath) = args.get("metrics") {
        let snap = xr_edge_dse::util::json::Json::parse_file(std::path::Path::new(mpath))?;
        if let Some(counters) = snap.get("counters").as_obj() {
            for (name, v) in counters {
                println!("  {name} = {}", v.as_u64().unwrap_or(0));
            }
            for base in ["eval.macro", "search.map"] {
                let hit = snap.get("counters").opt_f64(&format!("{base}.hit"), 0.0);
                let miss = snap.get("counters").opt_f64(&format!("{base}.miss"), 0.0);
                if hit + miss > 0.0 {
                    println!("  {base} hit rate: {}", pct(hit / (hit + miss)));
                }
            }
        }
    }
    Ok(())
}

/// Write every figure's data series as CSV (used by `make figures`). Each
/// series is a query with a CSV sink; Fig 5 streams its curves through
/// `for_each`, with the SRAM baseline emitted exactly once per panel
/// (the old loop duplicated it under both the P0 and P1 labels).
fn write_figure_csvs(out: &std::path::Path) -> anyhow::Result<usize> {
    std::fs::create_dir_all(out)?;
    let s = dse::paper_sweeper()?;
    let mut n = 0;

    // Fig 2(f): EDP vs node.
    Query::over(s.engine())
        .nodes(&Node::ALL)
        .assignments(Assignments::Flavors(vec![MemFlavor::SramOnly]))
        .to_csv(&["arch", "net", "node_nm", "energy_pj", "latency_ns", "edp"], |row| {
            let p = &row.point;
            vec![
                p.arch.clone(),
                p.network.clone(),
                format!("{}", p.node.nm()),
                sci(p.energy.total_pj()),
                sci(p.latency_ns),
                sci(p.edp()),
            ]
        })
        .save(&out.join("fig2f_edp.csv"))?;
    n += 1;

    // Fig 3(d) energies + Fig 4 breakdowns.
    Query::over(s.engine())
        .nodes(&[Node::N28, Node::N7])
        .to_csv(
            &["net", "node_nm", "arch", "flavor", "compute_pj", "mem_read_pj", "mem_write_pj"],
            |row| {
                let p = &row.point;
                vec![
                    p.network.clone(),
                    format!("{}", p.node.nm()),
                    p.arch.clone(),
                    p.flavor_label().into(),
                    sci(p.energy.compute_pj),
                    sci(p.energy.mem_read_pj()),
                    sci(p.energy.mem_write_pj()),
                ]
            },
        )
        .save(&out.join("fig3d_fig4_energy.csv"))?;
    n += 1;

    // Fig 5: P_mem vs IPS curves — SRAM baseline once per (arch × net),
    // then P0/P1 per MRAM device (a device axis in the query).
    fn curve(c: &mut Csv, p: &DesignPoint) {
        let mut ips = 0.05;
        while ips <= p.power.max_ips() && ips < 2e4 {
            c.row(vec![
                p.arch.clone(),
                p.network.clone(),
                p.flavor_label().into(),
                p.mram().label().into(),
                sci(ips),
                sci(p.p_mem_uw(ips)),
            ]);
            ips *= 1.5;
        }
    }
    let fig5 = Engine::new(
        vec![arch::simba(PeConfig::V2), arch::eyeriss(PeConfig::V2)],
        vec![workload::builtin::by_name("detnet")?, workload::builtin::by_name("edsnet")?],
    );
    let mut c = Csv::new(&["arch", "net", "flavor", "device", "ips", "p_mem_uw"]);
    Query::over(&fig5)
        .nodes(&[Node::N7])
        .devices(Devices::Fixed(Device::Sram))
        .assignments(Assignments::Flavors(vec![MemFlavor::SramOnly]))
        .for_each(|row| curve(&mut c, &row.point));
    Query::over(&fig5)
        .nodes(&[Node::N7])
        .devices(Devices::Each(Device::MRAMS.to_vec()))
        .assignments(Assignments::Flavors(vec![MemFlavor::P0, MemFlavor::P1]))
        .for_each(|row| curve(&mut c, &row.point));
    c.save(&out.join("fig5_ips_power.csv"))?;
    n += 1;
    Ok(n)
}

/// `run`: execute a `.xrdse` manifest, with `--set key=value` overrides
/// applied to the parsed tree before binding (dotted paths reach nested
/// blocks: `--set knobs.nodes=[28]`, `--set hand.seed=7`).
fn run_manifest(args: &xr_edge_dse::util::cli::Args) -> anyhow::Result<()> {
    let Some(path) = args.positional.first() else {
        anyhow::bail!("usage: xr-edge-dse run <manifest.xrdse> [--set key=value]...");
    };
    let spec = xr_edge_dse::manifest::load(std::path::Path::new(path), args.get_all("set"))?;
    xr_edge_dse::manifest::run(&spec)
}

/// `manifest check`: parse + validate manifests and print each one's
/// fully-resolved spec (every default written out) without running
/// anything. Exit status is the validation verdict.
fn manifest_cmd(args: &xr_edge_dse::util::cli::Args) -> anyhow::Result<()> {
    let usage = "usage: xr-edge-dse manifest check <manifest.xrdse>...";
    if args.positional.first().map(|s| s.as_str()) != Some("check") {
        anyhow::bail!("{usage}");
    }
    let files = &args.positional[1..];
    anyhow::ensure!(!files.is_empty(), "{usage}");
    for path in files {
        let spec = xr_edge_dse::manifest::load(std::path::Path::new(path), args.get_all("set"))?;
        println!("# {path}: ok — {} '{}', resolved:", spec.kind_label(), spec.name);
        print!("{}", spec.to_manifest());
    }
    Ok(())
}

/// `serve`: run the PJRT serving pipeline on synthetic sensor frames.
fn serve(args: &xr_edge_dse::util::cli::Args) -> anyhow::Result<()> {
    use xr_edge_dse::coordinator::{sensor::Sensor, Config, Coordinator};
    let model = args.get("model").unwrap().to_string();
    let fps = args.get_f64("fps")?.unwrap_or(10.0);
    let seconds = args.get_f64("seconds")?.unwrap_or(5.0);
    let artifacts = std::path::PathBuf::from(args.get("artifacts").unwrap());

    let coord = Coordinator::start(Config {
        artifacts_dir: artifacts,
        model: model.clone(),
        queue_depth: 4,
    })?;
    let mut sensor = if model.contains("eds") {
        Sensor::eye_camera(fps, 42)
    } else {
        Sensor::hand_camera(fps, 42)
    };
    let t0 = std::time::Instant::now();
    let mut submitted = 0u64;
    while t0.elapsed().as_secs_f64() < seconds {
        let gap = sensor.next_gap_s();
        std::thread::sleep(std::time::Duration::from_secs_f64(gap));
        coord.submit(sensor.capture());
        submitted += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    let dropped = coord.dropped_frames();
    let stats = coord.shutdown()?;
    print!("{}", stats.render(&format!("serve {model} @{fps} fps"), wall, dropped));
    println!("submitted {submitted}");
    Ok(())
}

fn print_help() {
    println!(
        "xr-edge-dse — memory-oriented DSE of edge-AI hardware for XR (tinyML'23 reproduction)\n\
         commands: map | energy | area | ips | edp | fig3d | pareto | hybrid | search | sweep | serve | scenario | fleet | run | manifest | obs | help\n\n{}",
        usage(&specs())
    );
}
