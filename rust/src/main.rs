//! `xr-edge-dse` CLI — the launcher over the DSE library and the serving
//! coordinator.
//!
//! ```text
//! xr-edge-dse map     --arch simba --net detnet          # mapper report
//! xr-edge-dse energy  --arch simba --net detnet --node 7 --flavor p1
//! xr-edge-dse area    --node 7                           # Table 2
//! xr-edge-dse ips     --node 7                           # Table 3
//! xr-edge-dse edp                                        # Fig 2(f)
//! xr-edge-dse fig3d                                      # Fig 3(d)
//! xr-edge-dse pareto  --node 7 --ips 10                  # undominated designs
//! xr-edge-dse sweep   --out artifacts/figures            # all CSV series
//! xr-edge-dse serve   --model detnet --fps 10 --seconds 5  # PJRT serving
//! ```
//!
//! All analytical commands route through the unified evaluation engine
//! (`xr_edge_dse::eval`): grids are sharded across threads (override with
//! `XR_DSE_THREADS`, 1 = sequential) with deterministic output ordering.

use xr_edge_dse::arch::{self, MemFlavor, PeConfig};
use xr_edge_dse::report::{pct, sci, Table};
use xr_edge_dse::tech::{paper_mram_for, Device, Node};
use xr_edge_dse::util::cli::{parse, usage, OptSpec};
use xr_edge_dse::{dse, energy, mapping, power, workload};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "arch", takes_value: true, help: "cpu|eyeriss|simba[_v1]", default: Some("simba") },
        OptSpec { name: "net", takes_value: true, help: "detnet|edsnet|tiny_cnn", default: Some("detnet") },
        OptSpec { name: "node", takes_value: true, help: "tech node nm (45|40|28|22|7)", default: Some("7") },
        OptSpec { name: "flavor", takes_value: true, help: "sram|p0|p1", default: Some("sram") },
        OptSpec { name: "device", takes_value: true, help: "stt|sot|vgsot (default: paper pick per node)", default: None },
        OptSpec { name: "ips", takes_value: true, help: "inference rate for power eval", default: Some("10") },
        OptSpec { name: "model", takes_value: true, help: "artifact model name for serve", default: Some("detnet") },
        OptSpec { name: "fps", takes_value: true, help: "sensor frame rate for serve", default: Some("10") },
        OptSpec { name: "seconds", takes_value: true, help: "serve duration", default: Some("5") },
        OptSpec { name: "artifacts", takes_value: true, help: "artifacts directory", default: Some("artifacts") },
        OptSpec { name: "out", takes_value: true, help: "output dir for sweep CSVs", default: Some("artifacts/figures") },
        OptSpec { name: "verbose", takes_value: false, help: "per-layer detail", default: None },
    ]
}

fn flavor_of(s: &str) -> anyhow::Result<MemFlavor> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "sram" | "sram-only" => MemFlavor::SramOnly,
        "p0" => MemFlavor::P0,
        "p1" => MemFlavor::P1,
        other => anyhow::bail!("unknown flavor '{other}'"),
    })
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let args = parse(&argv[1..], &specs())?;
    let node = Node::from_nm(args.get_usize("node")?.unwrap_or(7))?;
    let mram = match args.get("device") {
        Some(d) => Device::from_str(d)?,
        None => paper_mram_for(node),
    };

    match cmd.as_str() {
        "map" => {
            let a = arch::by_name(args.get("arch").unwrap())?;
            let net = workload::builtin::by_name(args.get("net").unwrap())?;
            let map = mapping::map_network(&a, &net);
            let mut t = Table::new(
                &format!("mapping {} on {}", net.name, a.name),
                &["layer", "macs", "cycles", "bw-bound", "util"],
            );
            for lm in &map.per_layer {
                if !args.flag("verbose") && lm.macs == 0.0 {
                    continue;
                }
                t.row(vec![
                    lm.layer.clone(),
                    sci(lm.macs),
                    sci(lm.cycles()),
                    if lm.bandwidth_cycles > lm.compute_cycles { "yes" } else { "no" }.into(),
                    format!("{:.3}", lm.macs / (lm.cycles() * a.total_macs() as f64).max(1.0)),
                ]);
            }
            print!("{}", t.render());
            println!(
                "total: {} MACs, {} cycles, avg util {:.3}",
                sci(map.total_macs()),
                sci(map.total_cycles()),
                map.utilization(&a)
            );
        }
        "energy" => {
            let a = arch::by_name(args.get("arch").unwrap())?;
            let net = workload::builtin::by_name(args.get("net").unwrap())?;
            let flavor = flavor_of(args.get("flavor").unwrap())?;
            let map = mapping::map_network(&a, &net);
            let b = energy::estimate(&a, &map, node, flavor, mram);
            let mut t = Table::new(
                &format!(
                    "energy {} on {} @{} {} ({})",
                    net.name,
                    a.name,
                    node.label(),
                    flavor.label(),
                    mram.label()
                ),
                &["component", "read (µJ)", "write (µJ)", "total (µJ)"],
            );
            let uj = 1e-6;
            t.row(vec!["compute".into(), "-".into(), "-".into(), format!("{:.3}", b.compute_pj * uj)]);
            for l in &b.levels {
                t.row(vec![
                    format!("{} [{}]", l.level, l.device.label()),
                    format!("{:.3}", l.read_pj * uj),
                    format!("{:.3}", l.write_pj * uj),
                    format!("{:.3}", (l.read_pj + l.write_pj) * uj),
                ]);
            }
            t.row(vec!["TOTAL".into(), format!("{:.3}", b.mem_read_pj() * uj), format!("{:.3}", b.mem_write_pj() * uj), format!("{:.3}", b.total_pj() * uj)]);
            print!("{}", t.render());
            let lat = energy::latency_ns(&a, &map, node, flavor, mram);
            println!("latency: {:.3} ms   EDP: {}", lat / 1e6, sci(energy::edp(b.total_pj(), lat)));
        }
        "area" => {
            let mut t = Table::new(
                &format!("Table 2 — area at {} ({})", node.label(), mram.label()),
                &["architecture", "SRAM-only (mm²)", "P0 (mm²)", "P1 (mm²)", "P0 saving", "P1 saving"],
            );
            for a in [arch::simba(PeConfig::V2), arch::eyeriss(PeConfig::V2)] {
                let base = xr_edge_dse::area::estimate(&a, node, MemFlavor::SramOnly, mram).total_mm2();
                let p0 = xr_edge_dse::area::estimate(&a, node, MemFlavor::P0, mram).total_mm2();
                let p1 = xr_edge_dse::area::estimate(&a, node, MemFlavor::P1, mram).total_mm2();
                t.row(vec![
                    a.name.clone(),
                    format!("{base:.2}"),
                    format!("{p0:.2}"),
                    format!("{p1:.2}"),
                    pct(1.0 - p0 / base),
                    pct(1.0 - p1 / base),
                ]);
            }
            print!("{}", t.render());
        }
        "ips" => {
            let rows = power::table3(
                &[
                    (workload::builtin::by_name("detnet")?, 10.0),
                    (workload::builtin::by_name("edsnet")?, 0.1),
                ],
                &[arch::simba(PeConfig::V2), arch::eyeriss(PeConfig::V2)],
                node,
                mram,
            );
            let mut t = Table::new(
                &format!("Table 3 — IPS analysis @{} v2 (64×64)", node.label()),
                &["workload", "arch", "IPS_min", "lat P0 (ms)", "lat P1 (ms)", "P_mem save P0", "P_mem save P1"],
            );
            for r in rows {
                t.row(vec![
                    r.workload,
                    r.arch,
                    format!("{}", r.ips_min),
                    format!("{:.2}", r.latency_p0_ms),
                    format!("{:.2}", r.latency_p1_ms),
                    pct(r.savings_p0),
                    pct(r.savings_p1),
                ]);
            }
            print!("{}", t.render());
        }
        "edp" => {
            let s = dse::paper_sweeper()?;
            let pts = s.grid(&Node::ALL, &[MemFlavor::SramOnly], paper_mram_for);
            let mut t = Table::new(
                "Fig 2(f) — EDP vs node (SRAM-only)",
                &["arch", "net", "node", "energy (µJ)", "latency (ms)", "EDP (µJ·ms)"],
            );
            for p in pts {
                t.row(vec![
                    p.arch.clone(),
                    p.network.clone(),
                    p.node.label(),
                    format!("{:.2}", p.energy.total_pj() * 1e-6),
                    format!("{:.3}", p.latency_ns / 1e6),
                    format!("{:.3}", p.energy.total_pj() * 1e-6 * p.latency_ns / 1e6),
                ]);
            }
            print!("{}", t.render());
        }
        "fig3d" => {
            let s = dse::paper_sweeper()?;
            let mut t = Table::new(
                "Fig 3(d) — single-inference energy, 9 variants × 2 nodes",
                &["net", "node", "arch", "flavor", "total (µJ)", "vs SRAM"],
            );
            let pts = dse::fig3d_grid(&s);
            for p in &pts {
                let base = pts
                    .iter()
                    .find(|q| {
                        q.arch == p.arch
                            && q.network == p.network
                            && q.node == p.node
                            && q.flavor == MemFlavor::SramOnly
                    })
                    .unwrap();
                t.row(vec![
                    p.network.clone(),
                    p.node.label(),
                    p.arch.clone(),
                    p.flavor.label().into(),
                    format!("{:.2}", p.energy.total_pj() * 1e-6),
                    pct(p.energy.total_pj() / base.energy.total_pj() - 1.0),
                ]);
            }
            print!("{}", t.render());
        }
        "hybrid" => {
            // §5's concluding suggestion, executable: enumerate every
            // NVM/SRAM split and rank by memory power at --ips.
            let a = arch::by_name(args.get("arch").unwrap())?;
            let net = workload::builtin::by_name(args.get("net").unwrap())?;
            let ips = args.get_f64("ips")?.unwrap_or(10.0);
            let map = mapping::map_network(&a, &net);
            let pts = dse::hybrid::sweep(&a, &map, node, mram, ips);
            let mut t = Table::new(
                &format!("hybrid NVM/SRAM splits — {} on {} @{} {} IPS (best first)",
                    net.name, a.name, node.label(), ips),
                &["MRAM levels", "P_mem (µW)", "E_mem/inf (µJ)", "retention (µW)", "area (mm²)"],
            );
            for p in pts.iter().take(8) {
                t.row(vec![
                    if p.mram_levels.is_empty() { "(none — SRAM-only)".into() } else { p.mram_levels.join("+") },
                    format!("{:.2}", p.p_mem_uw),
                    format!("{:.3}", p.e_mem_inf_pj * 1e-6),
                    format!("{:.2}", p.p_retention_uw),
                    format!("{:.2}", p.area_mm2),
                ]);
            }
            print!("{}", t.render());
            let p0 = dse::hybrid::flavor_mask(&a, MemFlavor::P0);
            let p1 = dse::hybrid::flavor_mask(&a, MemFlavor::P1);
            let find = |mask: u32| dse::hybrid::evaluate(&a, &map, node, mram, mask, ips).p_mem_uw;
            println!("named flavors: P0 {:.2} µW, P1 {:.2} µW, best split {:.2} µW",
                find(p0), find(p1), pts[0].p_mem_uw);
        }
        "pareto" => {
            // Which (arch × flavor) variants at --node are undominated in
            // (P_mem @ --ips, area, latency)? Engine-evaluated grid +
            // pareto::frontier, the §5 decision procedure as a command.
            let ips = args.get_f64("ips")?.unwrap_or(10.0);
            let net = workload::builtin::by_name(args.get("net").unwrap())?;
            let s = dse::Sweeper::new(
                vec![arch::cpu(), arch::eyeriss(PeConfig::V2), arch::simba(PeConfig::V2)],
                vec![net.clone()],
            );
            let pts: Vec<dse::DesignPoint> = s.grid(&[node], &MemFlavor::ALL, |_| mram);
            let feasible = dse::pareto::feasible(&pts, ips);
            let front = dse::pareto::frontier(&pts, ips);
            let mut t = Table::new(
                &format!(
                    "Pareto frontier — {} @{} {} IPS (engine grid, {} points)",
                    net.name,
                    node.label(),
                    ips,
                    pts.len()
                ),
                &["arch", "flavor", "P_mem (µW)", "area (mm²)", "latency (ms)", "feasible", "frontier"],
            );
            for (i, p) in pts.iter().enumerate() {
                let o = dse::pareto::objectives(p, ips);
                t.row(vec![
                    p.arch.clone(),
                    p.flavor.label().into(),
                    format!("{:.2}", o.p_mem_uw),
                    format!("{:.2}", o.area_mm2),
                    format!("{:.3}", o.latency_ms),
                    if feasible.contains(&i) { "yes" } else { "NO" }.into(),
                    if front.contains(&i) { "★" } else { "" }.into(),
                ]);
            }
            print!("{}", t.render());
        }
        "sweep" => {
            let out = std::path::PathBuf::from(args.get("out").unwrap());
            let n = write_figure_csvs(&out)?;
            println!("wrote {n} CSV series to {}", out.display());
        }
        "serve" => {
            serve(&args)?;
        }
        "help" | "--help" | "-h" => print_help(),
        other => {
            print_help();
            anyhow::bail!("unknown command '{other}'");
        }
    }
    Ok(())
}

/// Write every figure's data series as CSV (used by `make figures`).
fn write_figure_csvs(out: &std::path::Path) -> anyhow::Result<usize> {
    use xr_edge_dse::report::Csv;
    std::fs::create_dir_all(out)?;
    let s = dse::paper_sweeper()?;
    let mut n = 0;

    // Fig 2(f): EDP vs node.
    let mut c = Csv::new(&["arch", "net", "node_nm", "energy_pj", "latency_ns", "edp"]);
    for p in s.grid(&Node::ALL, &[MemFlavor::SramOnly], paper_mram_for) {
        c.row(vec![
            p.arch.clone(),
            p.network.clone(),
            format!("{}", p.node.nm()),
            sci(p.energy.total_pj()),
            sci(p.latency_ns),
            sci(p.edp()),
        ]);
    }
    c.save(&out.join("fig2f_edp.csv"))?;
    n += 1;

    // Fig 3(d) energies + Fig 4 breakdowns.
    let mut c = Csv::new(&[
        "net", "node_nm", "arch", "flavor", "compute_pj", "mem_read_pj", "mem_write_pj",
    ]);
    for p in dse::fig3d_grid(&s) {
        c.row(vec![
            p.network.clone(),
            format!("{}", p.node.nm()),
            p.arch.clone(),
            p.flavor.label().into(),
            sci(p.energy.compute_pj),
            sci(p.energy.mem_read_pj()),
            sci(p.energy.mem_write_pj()),
        ]);
    }
    c.save(&out.join("fig3d_fig4_energy.csv"))?;
    n += 1;

    // Fig 5: P_mem vs IPS curves for every device.
    let mut c = Csv::new(&["arch", "net", "flavor", "device", "ips", "p_mem_uw"]);
    for arch in [arch::simba(PeConfig::V2), arch::eyeriss(PeConfig::V2)] {
        for net in [workload::builtin::by_name("detnet")?, workload::builtin::by_name("edsnet")?] {
            let map = mapping::map_network(&arch, &net);
            for flavor in [MemFlavor::P0, MemFlavor::P1] {
                for device in Device::ALL {
                    let f = if device == Device::Sram { MemFlavor::SramOnly } else { flavor };
                    let pm = power::power_model(&arch, &map, Node::N7, f, device);
                    let mut ips = 0.05;
                    while ips <= pm.max_ips() && ips < 2e4 {
                        c.row(vec![
                            arch.name.clone(),
                            net.name.clone(),
                            flavor.label().into(),
                            device.label().into(),
                            sci(ips),
                            sci(pm.p_mem_uw(ips)),
                        ]);
                        ips *= 1.5;
                    }
                }
            }
        }
    }
    c.save(&out.join("fig5_ips_power.csv"))?;
    n += 1;
    Ok(n)
}

/// `serve`: run the PJRT serving pipeline on synthetic sensor frames.
fn serve(args: &xr_edge_dse::util::cli::Args) -> anyhow::Result<()> {
    use xr_edge_dse::coordinator::{sensor::Sensor, Config, Coordinator};
    let model = args.get("model").unwrap().to_string();
    let fps = args.get_f64("fps")?.unwrap_or(10.0);
    let seconds = args.get_f64("seconds")?.unwrap_or(5.0);
    let artifacts = std::path::PathBuf::from(args.get("artifacts").unwrap());

    let coord = Coordinator::start(Config {
        artifacts_dir: artifacts,
        model: model.clone(),
        queue_depth: 4,
    })?;
    let mut sensor = if model.contains("eds") {
        Sensor::eye_camera(fps, 42)
    } else {
        Sensor::hand_camera(fps, 42)
    };
    let t0 = std::time::Instant::now();
    let mut submitted = 0u64;
    while t0.elapsed().as_secs_f64() < seconds {
        let gap = sensor.next_gap_s();
        std::thread::sleep(std::time::Duration::from_secs_f64(gap));
        coord.submit(sensor.capture());
        submitted += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    let dropped = coord.dropped_frames();
    let stats = coord.shutdown()?;
    print!("{}", stats.render(&format!("serve {model} @{fps} fps"), wall, dropped));
    println!("submitted {submitted}");
    Ok(())
}

fn print_help() {
    println!(
        "xr-edge-dse — memory-oriented DSE of edge-AI hardware for XR (tinyML'23 reproduction)\n\
         commands: map | energy | area | ips | edp | fig3d | pareto | hybrid | sweep | serve | help\n\n{}",
        usage(&specs())
    );
}
