//! Minimal JSON parser + writer (serde_json substitute; see DESIGN.md
//! §Substitutions).
//!
//! Supports the full JSON grammar (RFC 8259) minus `\u` surrogate-pair
//! pedantry beyond the BMP. Numbers are kept as `f64`; the workload/config
//! files this crate exchanges with the python compile path only need f64
//! precision (integer counts < 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` so serialization is
/// deterministic (stable diffs for artifacts committed by `make artifacts`).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= 2f64.powi(53) {
                Some(n as u64)
            } else {
                None
            }
        })
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// `get` with an error message naming the key — config loading helper.
    pub fn req(&self, key: &str) -> crate::Result<&Json> {
        match self {
            Json::Obj(o) => o
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("missing key '{key}' in JSON object")),
            _ => anyhow::bail!("expected JSON object while looking up '{key}'"),
        }
    }

    pub fn req_f64(&self, key: &str) -> crate::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("key '{key}' is not a number"))
    }
    pub fn req_usize(&self, key: &str) -> crate::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("key '{key}' is not a non-negative integer"))
    }
    pub fn req_str(&self, key: &str) -> crate::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("key '{key}' is not a string"))
    }

    /// Optional f64 with default.
    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).as_f64().unwrap_or(default)
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- parsing ---------------------------------------------------------

    pub fn parse(text: &str) -> crate::Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> crate::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
    }

    // ---- writing ---------------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    // Integral values print without a fraction for readability.
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *n as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, b: u8) -> crate::Result<()> {
        match self.bump() {
            Some(x) if x == b => Ok(()),
            other => anyhow::bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos.saturating_sub(1),
                other.map(|c| c as char)
            ),
        }
    }
    fn literal(&mut self, lit: &str, v: Json) -> crate::Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> crate::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            ),
        }
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => anyhow::bail!(
                    "expected ',' or '}}' at byte {}, found {:?}",
                    self.pos.saturating_sub(1),
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => anyhow::bail!(
                    "expected ',' or ']' at byte {}, found {:?}",
                    self.pos.saturating_sub(1),
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if c >= 0xf0 {
                        4
                    } else if c >= 0xe0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.bytes.len());
                    self.pos = end;
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| anyhow::anyhow!("bad number '{text}': {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.25e2").unwrap(), Json::Num(-325.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_whitespace_and_empty() {
        assert_eq!(Json::parse(" { } ").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[\n]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo → ∞\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → ∞"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arch":"simba","pe":[64,64],"vmem_kib":16384.5,"nvm":true,"note":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn integral_numbers_print_without_fraction() {
        assert_eq!(Json::Num(64.0).to_string(), "64");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn accessors_and_req() {
        let v = Json::parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req_f64("missing").is_err());
        assert_eq!(v.opt_f64("missing", 7.5), 7.5);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
    }

    #[test]
    fn deterministic_object_order() {
        let v = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }
}
