//! Small statistics helpers shared by the coordinator metrics, the bench
//! harness, and the perf pass.

/// Online mean/min/max/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Accum {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accum {
    pub fn new() -> Self {
        Accum {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Percentile over an unsorted sample (nearest-rank on a sorted copy).
/// `q` in [0,1]. Returns NaN on an empty sample. Callers that need several
/// percentiles should sort once and use [`percentile_sorted`].
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(f64::total_cmp); // NaN-safe: total order instead of panicking partial_cmp
    percentile_sorted(&v, q)
}

/// Nearest-rank percentile over an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = ((q.clamp(0.0, 1.0)) * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank]
}

/// Latency summary used by coordinator metrics and the bench harness.
#[derive(Debug, Clone)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summarize(samples: &[f64]) -> Summary {
    let mut acc = Accum::new();
    for &s in samples {
        acc.push(s);
    }
    // One sorted copy serves every percentile (the old code cloned and
    // sorted the whole sample once per percentile — 3× the work).
    let mut sorted: Vec<f64> = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    Summary {
        count: samples.len(),
        mean: acc.mean(),
        p50: percentile_sorted(&sorted, 0.50),
        p95: percentile_sorted(&sorted, 0.95),
        p99: percentile_sorted(&sorted, 0.99),
        min: if samples.is_empty() { f64::NAN } else { acc.min() },
        max: if samples.is_empty() { f64::NAN } else { acc.max() },
    }
}

/// [`summarize`] over a sample that is already sorted: one sort serves
/// every percentile *and* min/max. This is the fleet-aggregation path —
/// the pooled cross-stream latency vector is sorted once and every
/// percentile afterwards is an O(1) rank lookup, instead of re-sorting
/// per percentile.
pub fn summarize_sorted(sorted: &[f64]) -> Summary {
    let mut acc = Accum::new();
    for &s in sorted {
        acc.push(s);
    }
    Summary {
        count: sorted.len(),
        mean: acc.mean(),
        p50: percentile_sorted(sorted, 0.50),
        p95: percentile_sorted(sorted, 0.95),
        p99: percentile_sorted(sorted, 0.99),
        min: sorted.first().copied().unwrap_or(f64::NAN),
        max: sorted.last().copied().unwrap_or(f64::NAN),
    }
}

/// Samples sorted once up front: any percentile afterwards is an O(1)
/// nearest-rank lookup ([`percentile_sorted`]), so aggregators that need
/// p50 *and* p99 (plus a [`Summary`]) never pay a second sort.
#[derive(Debug, Clone, Default)]
pub struct SortedSamples {
    sorted: Vec<f64>,
}

impl SortedSamples {
    pub fn new(mut samples: Vec<f64>) -> SortedSamples {
        samples.sort_by(f64::total_cmp);
        SortedSamples { sorted: samples }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn percentile(&self, q: f64) -> f64 {
        percentile_sorted(&self.sorted, q)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }

    pub fn summary(&self) -> Summary {
        summarize_sorted(&self.sorted)
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.sorted
    }
}

/// Geometric mean — used when aggregating energy ratios across workloads.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Linear interpolation helper for the IPS crossover solver.
pub fn lerp(a: f64, b: f64, t: f64) -> f64 {
    a + (b - a) * t
}

/// Relative difference |a-b| / max(|a|,|b|,eps) — tolerance checks between
/// the rust energy model and python-exported goldens.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accum_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut a = Accum::new();
        for &x in &xs {
            a.push(x);
        }
        assert_eq!(a.count(), 8);
        assert!((a.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic set is 32/7
        assert!((a.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(a.min(), 2.0);
        assert_eq!(a.max(), 9.0);
    }

    #[test]
    fn percentile_basics() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert!((percentile(&xs, 0.5) - 50.0).abs() <= 1.0);
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn summary_on_constant_stream() {
        let s = summarize(&[3.0; 10]);
        assert_eq!(s.count, 10);
        assert_eq!(s.p99, 3.0);
        assert_eq!(s.mean, 3.0);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0, 16.0]) - 4.0).abs() < 1e-12);
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn rel_diff_symmetric() {
        // the old assertion lacked .abs() and so could never fail when the
        // left side came out negative — now it constrains both directions
        assert!((rel_diff(100.0, 110.0) - rel_diff(110.0, 100.0)).abs() < 1e-15);
        assert!((rel_diff(3.0, 7.0) - rel_diff(7.0, 3.0)).abs() < 1e-15);
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
    }

    #[test]
    fn summary_percentiles_match_single_percentile_calls() {
        // deterministic shuffled-ish sample: summarize's shared sorted copy
        // must agree with the one-off percentile() path
        let xs: Vec<f64> = (0..200).map(|i| ((i * 7919) % 200) as f64).collect();
        let s = summarize(&xs);
        for (q, got) in [(0.50, s.p50), (0.95, s.p95), (0.99, s.p99)] {
            assert_eq!(got.to_bits(), percentile(&xs, q).to_bits());
        }
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 199.0);
    }
}
