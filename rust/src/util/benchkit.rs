//! Criterion-lite bench harness (criterion is not vendored offline):
//! warmup + timed iterations with mean/p50/min reporting, plus helpers the
//! figure benches share. Each `[[bench]]` target is a plain `fn main()`
//! that both *times* the model evaluation and *prints* the regenerated
//! table/figure, so `cargo bench | tee bench_output.txt` is a full
//! reproduction record.

use std::time::Instant;

/// Measure a closure: `warmup` unmeasured runs, then `iters` timed runs.
/// Returns (mean_s, min_s, p50_s) and prints a criterion-style line.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let p50 = crate::util::stats::percentile(&samples, 0.5);
    println!(
        "bench {name:<40} mean {:>10}  p50 {:>10}  min {:>10}  ({iters} iters)",
        fmt_s(mean),
        fmt_s(p50),
        fmt_s(min)
    );
    (mean, min, p50)
}

fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

/// Standard header every figure bench prints.
pub fn figure_header(id: &str, paper_claim: &str) {
    println!("\n================================================================");
    println!("{id}");
    println!("paper claim: {paper_claim}");
    println!("================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut n = 0;
        let (mean, min, p50) = bench("noop", 2, 10, || n += 1);
        assert_eq!(n, 12);
        assert!(mean >= min);
        assert!(p50 >= min);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_s(2.5e-9).ends_with("ns"));
        assert!(fmt_s(2.5e-5).ends_with("µs"));
        assert!(fmt_s(2.5e-2).ends_with("ms"));
        assert!(fmt_s(2.5).ends_with("s"));
    }
}
