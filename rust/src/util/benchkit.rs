//! Criterion-lite bench harness (criterion is not vendored offline):
//! warmup + timed iterations with mean/p50/min reporting, plus helpers the
//! figure benches share. Each `[[bench]]` target is a plain `fn main()`
//! that both *times* the model evaluation and *prints* the regenerated
//! table/figure, so `cargo bench | tee bench_output.txt` is a full
//! reproduction record.
//!
//! For the CI bench-regression harness every measured closure is also
//! recorded in-process; a bench binary that calls
//! [`write_json_if_requested`] before exiting dumps the records as JSON to
//! the path named by `XR_DSE_BENCH_JSON` (no-op when the variable is
//! unset). `ci/bench_regression.py` merges those files into `BENCH_5.json`
//! and gates them against `benches/baseline.json`.

use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// One recorded measurement (everything [`bench_units`] learned).
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub name: String,
    pub mean_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub iters: usize,
    /// Work units processed per timed iteration (e.g. design points per
    /// grid sweep); 0 = unspecified. The regression harness derives
    /// units/second as `units_per_iter / mean_s`.
    pub units_per_iter: f64,
    /// Extra numeric annotations ([`bench_annotate`]) — e.g. cache
    /// hit-rates — emitted as additional keys of the bench's JSON object.
    pub extras: Vec<(String, f64)>,
}

fn records() -> &'static Mutex<Vec<BenchRecord>> {
    static RECORDS: OnceLock<Mutex<Vec<BenchRecord>>> = OnceLock::new();
    RECORDS.get_or_init(|| Mutex::new(Vec::new()))
}

/// Measure a closure: `warmup` unmeasured runs, then `iters` timed runs.
/// Returns (mean_s, min_s, p50_s) and prints a criterion-style line.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> (f64, f64, f64) {
    bench_units(name, warmup, iters, 0.0, f)
}

/// [`bench`] with a work-unit annotation: `units_per_iter` names how many
/// design points / evaluations one timed iteration processes, so the
/// regression harness can report throughput (units/s) alongside wall time.
pub fn bench_units<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    units_per_iter: f64,
    mut f: F,
) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let p50 = crate::util::stats::percentile(&samples, 0.5);
    println!(
        "bench {name:<40} mean {:>10}  p50 {:>10}  min {:>10}  ({iters} iters)",
        fmt_s(mean),
        fmt_s(p50),
        fmt_s(min)
    );
    records().lock().unwrap().push(BenchRecord {
        name: name.to_string(),
        mean_s: mean,
        min_s: min,
        p50_s: p50,
        iters,
        units_per_iter,
        extras: Vec::new(),
    });
    (mean, min, p50)
}

/// Attach a numeric annotation to the most recent record named `name`
/// (e.g. a cache hit-rate the measured closure observed) — emitted as an
/// extra key of that bench's JSON object. A no-op when no such record
/// exists.
pub fn bench_annotate(name: &str, key: &str, value: f64) {
    let mut recs = records().lock().unwrap();
    if let Some(r) = recs.iter_mut().rev().find(|r| r.name == name) {
        r.extras.push((key.to_string(), value));
    }
}

/// Dump every bench recorded so far as JSON to `path` (one object per
/// bench: wall-time stats plus derived units/s when annotated).
pub fn write_json(path: &std::path::Path) -> crate::Result<()> {
    let recs = records().lock().unwrap();
    let mut benches = Vec::with_capacity(recs.len());
    for r in recs.iter() {
        let mut pairs = vec![
            ("name", Json::str(r.name.clone())),
            ("mean_s", Json::num(r.mean_s)),
            ("min_s", Json::num(r.min_s)),
            ("p50_s", Json::num(r.p50_s)),
            ("iters", Json::num(r.iters as f64)),
        ];
        if r.units_per_iter > 0.0 {
            pairs.push(("units_per_iter", Json::num(r.units_per_iter)));
            pairs.push(("units_per_s", Json::num(r.units_per_iter / r.mean_s.max(1e-12))));
        }
        for (k, v) in &r.extras {
            pairs.push((k.as_str(), Json::num(*v)));
        }
        benches.push(Json::obj(pairs));
    }
    let doc = Json::obj(vec![("benches", Json::Arr(benches))]);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, doc.to_pretty())?;
    Ok(())
}

/// [`write_json`] to the path named by the `XR_DSE_BENCH_JSON` env var —
/// the hook every bench binary calls before exiting; a no-op when the
/// variable is unset (interactive `cargo bench` runs are unaffected).
pub fn write_json_if_requested() -> crate::Result<()> {
    match std::env::var("XR_DSE_BENCH_JSON") {
        Ok(path) if !path.is_empty() => write_json(std::path::Path::new(&path)),
        _ => Ok(()),
    }
}

fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

/// Standard header every figure bench prints.
pub fn figure_header(id: &str, paper_claim: &str) {
    println!("\n================================================================");
    println!("{id}");
    println!("paper claim: {paper_claim}");
    println!("================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut n = 0;
        let (mean, min, p50) = bench("noop", 2, 10, || n += 1);
        assert_eq!(n, 12);
        assert!(mean >= min);
        assert!(p50 >= min);
    }

    #[test]
    fn bench_units_records_throughput_json() {
        bench_units("unit-bench-json", 0, 3, 36.0, || {
            std::hint::black_box(1 + 1);
        });
        bench_annotate("unit-bench-json", "cache_hit_rate", 0.75);
        bench_annotate("no-such-bench", "ignored", 1.0); // must not panic
        let dir = std::env::temp_dir().join(format!("xr_dse_bench_{}", std::process::id()));
        let path = dir.join("bench.json");
        write_json(&path).unwrap();
        let doc = Json::parse_file(&path).unwrap();
        let benches = doc.req("benches").unwrap().as_arr().unwrap().to_vec();
        let rec = benches
            .iter()
            .find(|b| b.get("name").as_str() == Some("unit-bench-json"))
            .expect("recorded bench present");
        assert_eq!(rec.req_f64("units_per_iter").unwrap(), 36.0);
        assert!(rec.req_f64("units_per_s").unwrap() > 0.0);
        assert!(rec.req_f64("mean_s").unwrap() >= 0.0);
        assert_eq!(rec.req_f64("cache_hit_rate").unwrap(), 0.75);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_s(2.5e-9).ends_with("ns"));
        assert!(fmt_s(2.5e-5).ends_with("µs"));
        assert!(fmt_s(2.5e-2).ends_with("ms"));
        assert!(fmt_s(2.5).ends_with("s"));
    }
}
