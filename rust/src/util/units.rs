//! Unit conventions and pretty-printers.
//!
//! Internal convention (documented once, asserted everywhere):
//! - energy: **picojoules (pJ)** — component energies from the literature
//!   are naturally pJ/access at these nodes;
//! - time: **nanoseconds (ns)** for latency, seconds for rates;
//! - power: **microwatts (µW)** for the Fig-5 memory-power axis;
//! - area: **µm²** internally, reported in mm²;
//! - capacity: bytes.

pub const PJ_PER_UJ: f64 = 1e6;
pub const NS_PER_MS: f64 = 1e6;
pub const NS_PER_S: f64 = 1e9;
pub const UM2_PER_MM2: f64 = 1e6;

/// pJ energy consumed at a given rate (1/s) → average power in µW.
/// 1 pJ × 1 Hz = 1e-12 W = 1e-6 µW.
pub fn pj_at_rate_to_uw(energy_pj: f64, rate_hz: f64) -> f64 {
    energy_pj * rate_hz * 1e-6
}

/// Human-readable engineering notation, e.g. `format_si(3.2e-5, "J")`.
pub fn format_si(value: f64, unit: &str) -> String {
    if value == 0.0 || !value.is_finite() {
        return format!("{value} {unit}");
    }
    const PREFIXES: &[(f64, &str)] = &[
        (1e12, "T"),
        (1e9, "G"),
        (1e6, "M"),
        (1e3, "k"),
        (1.0, ""),
        (1e-3, "m"),
        (1e-6, "µ"),
        (1e-9, "n"),
        (1e-12, "p"),
        (1e-15, "f"),
    ];
    let mag = value.abs();
    for &(scale, prefix) in PREFIXES {
        if mag >= scale {
            return format!("{:.3} {}{}", value / scale, prefix, unit);
        }
    }
    format!("{value:.3e} {unit}")
}

/// Bytes with binary prefix.
pub fn format_bytes(bytes: usize) -> String {
    const UNITS: &[&str] = &["B", "KiB", "MiB", "GiB"];
    let mut v = bytes as f64;
    let mut i = 0;
    while v >= 1024.0 && i + 1 < UNITS.len() {
        v /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_conversion() {
        // 100 pJ per inference at 10 IPS = 1e-9 W = 1e-3 µW
        assert!((pj_at_rate_to_uw(100.0, 10.0) - 1e-3).abs() < 1e-18);
        // 1e6 pJ (1 µJ) at 1000 Hz = 1 mW = 1000 µW
        assert!((pj_at_rate_to_uw(1e6, 1000.0) - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(format_si(3200.0, "J"), "3.200 kJ");
        assert_eq!(format_si(0.0032, "W"), "3.200 mW");
        assert_eq!(format_si(4.2e-12, "J"), "4.200 pJ");
        assert_eq!(format_si(0.0, "J"), "0 J");
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(format_bytes(512), "512 B");
        assert_eq!(format_bytes(12 * 1024), "12.00 KiB");
        assert_eq!(format_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
