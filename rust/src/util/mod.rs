//! Environment substrates: the offline build vendored only the `xla` crate
//! closure, so the usual ecosystem crates (serde_json, rand, clap) are
//! re-implemented here as small, well-tested modules.

pub mod json;
pub mod prng;
pub mod stats;
pub mod cli;
pub mod units;
pub mod benchkit;
