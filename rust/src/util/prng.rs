//! Deterministic PRNG (rand substitute): xoshiro256++ — fast, good quality,
//! and reproducible across runs (workload generators, property tests, and
//! synthetic sensor streams all need seeded determinism).

/// xoshiro256++ by Blackman & Vigna (public domain reference implementation).
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via SplitMix64 so any u64 (including 0) yields a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Prng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [lo, hi) — rejection-free Lemire reduction.
    /// Panics on an empty range (`hi <= lo`): search strategies feed these
    /// from user-supplied knob spaces, so the failure must name itself.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "Prng::range_u64: empty range [{lo}, {hi})");
        let span = hi - lo;
        lo + ((self.next_u64() as u128 * span as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi); panics with a clear message on an
    /// empty range (see [`Prng::range_u64`]).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "Prng::range_usize: empty range [{lo}, {hi})");
        self.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Standard normal via Box–Muller (cached second variate omitted for
    /// simplicity; call rate here is far from hot).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential inter-arrival with the given rate (events/sec); used by
    /// the coordinator's Poisson frame sources.
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Fisher–Yates shuffle. Degenerate slices (empty or single-element)
    /// are a no-op by construction — the internal ranges are never empty.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.range_usize(0, i + 1);
            items.swap(i, j);
        }
    }

    /// Uniform pick; panics with a clear message on an empty slice rather
    /// than an opaque index-out-of-bounds from the range reduction.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty(), "Prng::pick on an empty slice");
        &items[self.range_usize(0, items.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Prng::new(1), Prng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut p = Prng::new(42);
        let n = 10_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = p.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn range_u64_bounds() {
        let mut p = Prng::new(3);
        for _ in 0..1000 {
            let x = p.range_u64(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut p = Prng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| p.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut p = Prng::new(11);
        let rate = 4.0;
        let n = 20_000;
        let mean = (0..n).map(|_| p.exp(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.02, "mean={mean}");
    }

    #[test]
    #[should_panic(expected = "Prng::range_usize: empty range [5, 5)")]
    fn empty_usize_range_names_itself() {
        Prng::new(1).range_usize(5, 5);
    }

    #[test]
    #[should_panic(expected = "Prng::range_u64: empty range [9, 3)")]
    fn inverted_u64_range_names_itself() {
        Prng::new(1).range_u64(9, 3);
    }

    #[test]
    #[should_panic(expected = "Prng::pick on an empty slice")]
    fn pick_from_empty_slice_names_itself() {
        Prng::new(1).pick::<u8>(&[]);
    }

    #[test]
    fn shuffle_of_degenerate_slices_is_noop() {
        let mut p = Prng::new(5);
        let mut empty: [u8; 0] = [];
        p.shuffle(&mut empty);
        let mut one = [7u8];
        p.shuffle(&mut one);
        assert_eq!(one, [7]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
