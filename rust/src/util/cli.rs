//! Tiny CLI argument parser (clap substitute). Supports subcommands,
//! `--flag`, `--key value` / `--key=value`, and positionals; generates a
//! usage string from the declared options.

use std::collections::BTreeMap;

/// Declarative option spec: name (without `--`), takes-value?, help, default.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed arguments: options (flags map to "true"), positionals. For
/// repeatable options every occurrence is also collected in order
/// (`get_all`); `opts` keeps the last one (the historical behavior).
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub opts: BTreeMap<String, String>,
    pub positional: Vec<String>,
    /// Every occurrence of each value-taking option, in argv order
    /// (defaults are not included).
    pub multi: BTreeMap<String, Vec<String>>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }
    /// All occurrences of a repeatable option (`--set a=1 --set b=2`), in
    /// order. Empty when the option never appeared on the command line.
    pub fn get_all(&self, name: &str) -> &[String] {
        self.multi.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }
    pub fn flag(&self, name: &str) -> bool {
        self.get(name) == Some("true")
    }
    pub fn get_f64(&self, name: &str) -> crate::Result<Option<f64>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{name}: {e}")),
        }
    }
    pub fn get_usize(&self, name: &str) -> crate::Result<Option<usize>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{name}: {e}")),
        }
    }
}

/// Parse `argv` (not including the program/subcommand name) against specs.
pub fn parse(argv: &[String], specs: &[OptSpec]) -> crate::Result<Args> {
    let mut args = Args::default();
    for s in specs {
        if let Some(d) = s.default {
            args.opts.insert(s.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(stripped) = a.strip_prefix("--") {
            let (name, inline_val) = match stripped.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (stripped, None),
            };
            let spec = specs
                .iter()
                .find(|s| s.name == name)
                .ok_or_else(|| anyhow::anyhow!("unknown option --{name}\n{}", usage(specs)))?;
            let value = if spec.takes_value {
                match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| anyhow::anyhow!("--{name} requires a value"))?
                    }
                }
            } else {
                if inline_val.is_some() {
                    anyhow::bail!("--{name} does not take a value");
                }
                "true".to_string()
            };
            if spec.takes_value {
                args.multi.entry(name.to_string()).or_default().push(value.clone());
            }
            args.opts.insert(name.to_string(), value);
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

/// Render a usage block from the specs.
pub fn usage(specs: &[OptSpec]) -> String {
    let mut s = String::from("options:\n");
    for spec in specs {
        let head = if spec.takes_value {
            format!("  --{} <v>", spec.name)
        } else {
            format!("  --{}", spec.name)
        };
        let default = spec
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("{head:<24} {}{default}\n", spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec {
                name: "node",
                takes_value: true,
                help: "tech node",
                default: Some("7"),
            },
            OptSpec {
                name: "verbose",
                takes_value: false,
                help: "chatty",
                default: None,
            },
        ]
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], &specs()).unwrap();
        assert_eq!(a.get("node"), Some("7"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&sv(&["--node", "28"]), &specs()).unwrap();
        assert_eq!(a.get("node"), Some("28"));
        let a = parse(&sv(&["--node=22"]), &specs()).unwrap();
        assert_eq!(a.get("node"), Some("22"));
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&sv(&["--verbose", "detnet", "simba"]), &specs()).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["detnet", "simba"]);
    }

    #[test]
    fn errors() {
        assert!(parse(&sv(&["--bogus"]), &specs()).is_err());
        assert!(parse(&sv(&["--node"]), &specs()).is_err());
        assert!(parse(&sv(&["--verbose=1"]), &specs()).is_err());
    }

    #[test]
    fn repeated_options_collect_in_order() {
        let a = parse(&sv(&["--node", "28", "--node=7"]), &specs()).unwrap();
        assert_eq!(a.get("node"), Some("7"));
        assert_eq!(a.get_all("node"), ["28".to_string(), "7".to_string()]);
        assert!(a.get_all("verbose").is_empty());
    }

    #[test]
    fn typed_getters() {
        let a = parse(&sv(&["--node", "28"]), &specs()).unwrap();
        assert_eq!(a.get_f64("node").unwrap(), Some(28.0));
        assert_eq!(a.get_usize("node").unwrap(), Some(28));
        let a = parse(&sv(&["--node", "x"]), &specs()).unwrap();
        assert!(a.get_f64("node").is_err());
    }
}
