//! Accelergy-lite: combine Timeloop-lite access counts with the CACTI-lite
//! macro energies and the compute-energy table to produce per-inference
//! energy with compute / memory-read / memory-write breakdowns
//! (Fig 2(e), Fig 3(d), Fig 4).
//!
//! Since the unified-engine refactor, [`estimate`] and [`latency_ns`] are
//! thin wrappers over [`crate::eval::EvalContext`] — the macro models,
//! level totals and per-level bus transactions are built once there and
//! shared with the power/area/DSE paths.

use crate::arch::{Arch, MemFlavor};
use crate::eval::{DeviceAssignment, EvalContext, MacroSet};
use crate::mapping::NetworkMap;
use crate::tech::{Device, Node};

/// Per-level energy contribution (pJ per inference).
#[derive(Debug, Clone)]
pub struct LevelEnergy {
    pub level: String,
    pub device: Device,
    /// SRAM/MRAM macro (true) vs FF register file (false). Fig 4's
    /// read/write NVM analysis concerns macros only; register files are
    /// CMOS datapath state and never replaced.
    pub is_macro: bool,
    pub read_pj: f64,
    pub write_pj: f64,
}

/// Full per-inference energy breakdown (pJ).
#[derive(Debug, Clone)]
pub struct EnergyBreakdown {
    pub arch: String,
    pub network: String,
    pub node: Node,
    /// The named flavor this breakdown was evaluated at; `None` for
    /// arbitrary hybrid lattice points.
    pub flavor: Option<MemFlavor>,
    pub mram: Device,
    pub compute_pj: f64,
    pub levels: Vec<LevelEnergy>,
}

impl EnergyBreakdown {
    pub fn mem_read_pj(&self) -> f64 {
        self.levels.iter().map(|l| l.read_pj).sum()
    }
    pub fn mem_write_pj(&self) -> f64 {
        self.levels.iter().map(|l| l.write_pj).sum()
    }
    /// Macro-only (SRAM/MRAM) read energy — the Fig-4 series.
    pub fn macro_read_pj(&self) -> f64 {
        self.levels.iter().filter(|l| l.is_macro).map(|l| l.read_pj).sum()
    }
    /// Macro-only (SRAM/MRAM) write energy — the Fig-4 series.
    pub fn macro_write_pj(&self) -> f64 {
        self.levels.iter().filter(|l| l.is_macro).map(|l| l.write_pj).sum()
    }
    pub fn mem_pj(&self) -> f64 {
        self.mem_read_pj() + self.mem_write_pj()
    }
    pub fn total_pj(&self) -> f64 {
        self.compute_pj + self.mem_pj()
    }
    /// Memory energy restricted to weight-holding levels (Fig 5's "weight"
    /// power series).
    pub fn weight_mem_pj(&self, arch: &Arch) -> f64 {
        self.levels
            .iter()
            .filter(|l| {
                arch.level(&l.level)
                    .map(|lvl| {
                        matches!(
                            lvl.role,
                            crate::arch::BufferRole::Weight | crate::arch::BufferRole::GlobalWeight
                        )
                    })
                    .unwrap_or(false)
            })
            .map(|l| l.read_pj + l.write_pj)
            .sum()
    }
}

/// Estimate the energy of one inference for a mapped network (thin wrapper
/// over the unified engine).
pub fn estimate(
    arch: &Arch,
    map: &NetworkMap,
    node: Node,
    flavor: MemFlavor,
    mram: Device,
) -> EnergyBreakdown {
    let assignment = DeviceAssignment::from_flavor(arch, flavor, mram);
    EvalContext::new(arch, map, node, assignment).energy_breakdown()
}

/// Convenience: map + estimate in one call with the paper's node-appropriate
/// MRAM device ([`crate::tech::paper_mram_for`]).
pub fn estimate_paper_variant(
    arch: &Arch,
    net: &crate::workload::Network,
    node: Node,
    flavor: MemFlavor,
) -> EnergyBreakdown {
    let map = crate::mapping::map_network(arch, net);
    estimate(arch, &map, node, flavor, crate::tech::paper_mram_for(node))
}

/// Inference latency in ns for a mapped network at a node/flavor (thin
/// wrapper over the unified engine's memory-bounded clock — uses the
/// static [`MacroSet`] only, no energy derivation).
pub fn latency_ns(
    arch: &Arch,
    map: &NetworkMap,
    node: Node,
    flavor: MemFlavor,
    mram: Device,
) -> f64 {
    let assignment = DeviceAssignment::from_flavor(arch, flavor, mram);
    let clock_mhz = MacroSet::new(arch, node, assignment).clock_mhz();
    map.total_cycles() / clock_mhz * 1e3 // cycles / MHz = µs → ns ×1e3
}

/// Energy-delay product (J·s scaled: pJ × ns = 1e-21 J·s); reported raw for
/// relative comparisons (Fig 2(f)).
pub fn edp(energy_pj: f64, latency_ns: f64) -> f64 {
    energy_pj * latency_ns
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{cpu, eyeriss, simba, PeConfig};
    use crate::mapping::map_network;
    use crate::workload::builtin::{detnet, edsnet};

    fn breakdown(arch: &Arch, node: Node, flavor: MemFlavor) -> EnergyBreakdown {
        let net = detnet();
        let map = map_network(arch, &net);
        estimate(arch, &map, node, flavor, crate::tech::paper_mram_for(node))
    }

    #[test]
    fn memory_dominates_on_systolic_compute_on_cpu() {
        // Fig 2(e): "memory power dissipation is far more significant than
        // that of compute" for Eyeriss/Simba; reversed for the CPU.
        for arch in [eyeriss(PeConfig::V2), simba(PeConfig::V2)] {
            let b = breakdown(&arch, Node::N40, MemFlavor::SramOnly);
            assert!(b.mem_pj() > b.compute_pj, "{}: mem must dominate", arch.name);
        }
        let b = breakdown(&cpu(), Node::N45, MemFlavor::SramOnly);
        assert!(b.compute_pj > b.mem_pj(), "cpu: compute must dominate");
    }

    #[test]
    fn node_scaling_reduces_energy() {
        let arch = simba(PeConfig::V2);
        let e40 = breakdown(&arch, Node::N40, MemFlavor::SramOnly).total_pj();
        let e7 = breakdown(&arch, Node::N7, MemFlavor::SramOnly).total_pj();
        let ratio = e40 / e7;
        assert!((2.0..6.0).contains(&ratio), "40→7nm ratio {ratio}");
    }

    #[test]
    fn p0_saves_at_28nm_reverses_at_7nm() {
        // §5 bullet 3: STT@28 is read-optimized → P0 saves; VGSOT@7 is
        // write-optimized → P0 costs (weight traffic is read-dominated).
        for arch in [eyeriss(PeConfig::V2), simba(PeConfig::V2)] {
            let sram28 = breakdown(&arch, Node::N28, MemFlavor::SramOnly).total_pj();
            let p028 = breakdown(&arch, Node::N28, MemFlavor::P0).total_pj();
            assert!(p028 < sram28, "{}: P0@28 must save ({p028} vs {sram28})", arch.name);

            let sram7 = breakdown(&arch, Node::N7, MemFlavor::SramOnly).total_pj();
            let p07 = breakdown(&arch, Node::N7, MemFlavor::P0).total_pj();
            assert!(p07 > sram7, "{}: P0@7 must cost ({p07} vs {sram7})", arch.name);
        }
    }

    #[test]
    fn p1_always_costs_more_energy_per_inference() {
        // §5 bullet 2: P1 shows higher energy for all arch/workloads/nodes.
        for arch in [eyeriss(PeConfig::V2), simba(PeConfig::V2), cpu()] {
            for node in [Node::N28, Node::N7] {
                let sram = breakdown(&arch, node, MemFlavor::SramOnly).total_pj();
                let p1 = breakdown(&arch, node, MemFlavor::P1).total_pj();
                assert!(
                    p1 > sram,
                    "{} @{node:?}: P1 {p1} must exceed SRAM {sram}",
                    arch.name
                );
            }
        }
    }

    #[test]
    fn cpu_nvm_impact_is_small() {
        // §5 bullet 1: CPU energy "nearly equivalent" across flavors
        // (compute-dominated).
        let sram = breakdown(&cpu(), Node::N7, MemFlavor::SramOnly).total_pj();
        let p1 = breakdown(&cpu(), Node::N7, MemFlavor::P1).total_pj();
        let delta = (p1 - sram).abs() / sram;
        assert!(delta < 0.35, "cpu P1 delta {delta}");
    }

    #[test]
    fn p1_7nm_reads_dominate_writes_heavily() {
        // Fig 4: at P1-7nm memory reads dominate writes overwhelmingly
        // (paper: ≈50× on their access mix; our mapping keeps symmetric
        // accumulation-buffer traffic in the split, which bounds the ratio
        // lower — see EXPERIMENTS.md §Deviations). Assert the *shape*: the
        // VGSOT asymmetry amplifies read-dominance well beyond the
        // SRAM-only baseline, and Eyeriss (pure read-path weights) exceeds
        // 10×.
        for arch in [eyeriss(PeConfig::V2), simba(PeConfig::V2)] {
            let sram = breakdown(&arch, Node::N7, MemFlavor::SramOnly);
            let p1 = breakdown(&arch, Node::N7, MemFlavor::P1);
            let base = sram.macro_read_pj() / sram.macro_write_pj();
            let ratio = p1.macro_read_pj() / p1.macro_write_pj();
            assert!(ratio > 3.0, "{}: read/write ratio {ratio}", arch.name);
            assert!(ratio > 2.0 * base, "{}: {ratio} vs baseline {base}", arch.name);
        }
        let ey = breakdown(&eyeriss(PeConfig::V2), Node::N7, MemFlavor::P1);
        assert!(ey.macro_read_pj() / ey.macro_write_pj() > 10.0);
    }

    #[test]
    fn p1_28nm_writes_dominate_for_eyeriss() {
        // Fig 4: at 28 nm (STT write-expensive) the trend reverses for
        // Eyeriss (write-heavy spad refills).
        let b = breakdown(&eyeriss(PeConfig::V2), Node::N28, MemFlavor::P1);
        assert!(
            b.macro_write_pj() > b.macro_read_pj(),
            "write {} vs read {}",
            b.macro_write_pj(),
            b.macro_read_pj()
        );
    }

    #[test]
    fn latency_edsnet_much_larger_than_detnet() {
        let arch = simba(PeConfig::V2);
        let d = map_network(&arch, &detnet());
        let e = map_network(&arch, &edsnet());
        let ld = latency_ns(&arch, &d, Node::N7, MemFlavor::P0, Device::VgsotMram);
        let le = latency_ns(&arch, &e, Node::N7, MemFlavor::P0, Device::VgsotMram);
        // Table 3: 0.34 ms vs 48.57 ms ≈ 140×
        let ratio = le / ld;
        assert!((20.0..1000.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn p1_latency_penalty_moderate() {
        // §5: P1 incurs ≈20% higher inference latency (MRAM-limited clock).
        let arch = simba(PeConfig::V2);
        let map = map_network(&arch, &detnet());
        let p0 = latency_ns(&arch, &map, Node::N7, MemFlavor::P0, Device::VgsotMram);
        let p1 = latency_ns(&arch, &map, Node::N7, MemFlavor::P1, Device::VgsotMram);
        assert!(p1 >= p0);
        assert!(p1 / p0 < 3.0, "p1/p0 = {}", p1 / p0);
    }
}
