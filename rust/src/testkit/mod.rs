//! proptest-lite: a deterministic property-testing harness (proptest is not
//! vendored in the offline environment — see DESIGN.md §Substitutions).
//!
//! Usage (`no_run`: doctest binaries miss the xla rpath in this repo):
//! ```no_run
//! use xr_edge_dse::testkit::{Gen, check};
//! check("addition commutes", 200, |g| {
//!     let (a, b) = (g.f64_in(-1e6, 1e6), g.f64_in(-1e6, 1e6));
//!     assert!((a + b - (b + a)).abs() < 1e-9);
//! });
//! ```
//!
//! Every case is generated from a seed derived from (property name, case
//! index), so a failure report like `property 'x' failed on case 17
//! (seed 0x...)` reproduces exactly with `replay("x", 17, |g| ...)`.

use crate::util::prng::Prng;

/// Random-input generator handed to each property case.
pub struct Gen {
    rng: Prng,
    /// Trace of drawn values for the failure report.
    trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen {
            rng: Prng::new(seed),
            trace: Vec::new(),
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.range_usize(lo, hi);
        self.trace.push(format!("usize_in({lo},{hi})={v}"));
        v
    }

    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        let v = self.rng.range_u64(lo, hi);
        self.trace.push(format!("u64_in({lo},{hi})={v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.range_f64(lo, hi);
        self.trace.push(format!("f64_in({lo},{hi})={v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.bool(0.5);
        self.trace.push(format!("bool()={v}"));
        v
    }

    /// Pick one of the provided choices (cloned).
    pub fn choose<T: Clone + std::fmt::Debug>(&mut self, items: &[T]) -> T {
        let v = self.rng.pick(items).clone();
        self.trace.push(format!("choose={v:?}"));
        v
    }

    /// A power of two in [2^lo_exp, 2^hi_exp].
    pub fn pow2(&mut self, lo_exp: u32, hi_exp: u32) -> usize {
        let e = self.rng.range_u64(lo_exp as u64, hi_exp as u64 + 1) as u32;
        let v = 1usize << e;
        self.trace.push(format!("pow2({lo_exp},{hi_exp})={v}"));
        v
    }

    /// Vector of f64s.
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.rng.range_f64(lo, hi)).collect()
    }
}

fn case_seed(name: &str, case: u64) -> u64 {
    // FNV-1a over the name, mixed with the case index.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ case.wrapping_mul(0x9e3779b97f4a7c15)
}

/// Run `cases` random cases of the property. Panics (with the generator
/// trace) on the first failing case.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: u64, mut prop: F) {
    for case in 0..cases {
        let seed = case_seed(name, case);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}):\n  {msg}\n  drawn: [{}]\n  replay with testkit::replay(\"{name}\", {case}, ...)",
                g.trace.join(", ")
            );
        }
    }
}

/// Re-run a single failing case by (name, case index).
pub fn replay<F: FnMut(&mut Gen)>(name: &str, case: u64, mut prop: F) {
    let mut g = Gen::new(case_seed(name, case));
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 50, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!((0.0..1.0).contains(&x));
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails' failed on case")]
    fn failing_property_reports_case() {
        check("fails", 20, |g| {
            let x = g.usize_in(0, 100);
            assert!(x < 40, "x={x} too big");
        });
    }

    #[test]
    fn replay_reproduces_case_values() {
        let mut first: Option<(usize, f64)> = None;
        replay("repro", 3, |g| {
            first = Some((g.usize_in(0, 1000), g.f64_in(-1.0, 1.0)));
        });
        let mut second: Option<(usize, f64)> = None;
        replay("repro", 3, |g| {
            second = Some((g.usize_in(0, 1000), g.f64_in(-1.0, 1.0)));
        });
        assert_eq!(first, second);
        assert!(first.is_some());
    }

    #[test]
    fn pow2_in_range() {
        check("pow2 range", 100, |g| {
            let v = g.pow2(3, 10);
            assert!(v.is_power_of_two());
            assert!((8..=1024).contains(&v));
        });
    }
}
