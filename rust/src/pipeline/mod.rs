//! Temporal operation-cycle simulator (Fig 3(a)-(b)): WU (wakeup) → FA
//! (frame acquisition) → AI Inference → PG (power-gate), repeated per
//! inference event. Used by the power-gate controller in the coordinator
//! and by the Fig-3 bench to visualize the SRAM-vs-NVM activity profiles.

use crate::power::PowerModel;

/// Execution modes of the XR-AI pipeline (Fig 3(a)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Wakeup,
    FrameAcquire,
    Inference,
    PowerGated,
    /// SRAM retention while idle (the SRAM-only pipeline cannot fully gate).
    Retention,
}

/// One segment of the simulated timeline.
#[derive(Debug, Clone)]
pub struct Segment {
    pub mode: Mode,
    pub start_ns: f64,
    pub dur_ns: f64,
    /// Average memory power during this segment, µW.
    pub power_uw: f64,
}

/// Frame-acquisition time: sensor readout, modeled at 1 ms (camera MIPI
/// readout of a small ROI; overlaps are ignored as in the paper's Fig 3).
pub const FRAME_ACQ_NS: f64 = 1_000_000.0;

/// Simulate `n_frames` periodic inference events at `ips` and return the
/// timeline plus the average memory power (which converges to
/// [`PowerModel::p_mem_uw`] — property-tested below).
///
/// NVM-ness and retention are independent axes (the old code inferred
/// "NVM" from `p_retention_uw == 0`, which mis-modeled the hybrid P0
/// profile: NVM weight macros *do* wake, retained activation SRAM *does*
/// leak): a wakeup segment is emitted whenever the model pays a wakeup
/// energy, and the retained SRAM's leakage is a continuous background
/// power across every segment — matching the gate controller's ledger.
pub fn simulate(model: &PowerModel, ips: f64, n_frames: usize) -> (Vec<Segment>, f64) {
    let period_ns = 1e9 / ips;
    let has_nvm = model.e_wakeup_pj > 0.0;
    let retains = model.p_retention_uw > 0.0;
    let wakeup_ns = if has_nvm { crate::mem::WAKEUP_NS } else { 0.0 };
    let p_ret = model.p_retention_uw;
    let mut segs = Vec::new();
    let mut energy_pj = 0.0;
    let mut t = 0.0;
    for _ in 0..n_frames {
        let frame_start = t;
        if has_nvm {
            // Wakeup: rail charge, energy charged from the model.
            let p = model.e_wakeup_pj / wakeup_ns.max(1.0) * 1e3 + p_ret; // pJ/ns → µW ×1e3
            segs.push(Segment { mode: Mode::Wakeup, start_ns: t, dur_ns: wakeup_ns, power_uw: p });
            energy_pj += model.e_wakeup_pj + p_ret * wakeup_ns * 1e-3;
            t += wakeup_ns;
        }
        segs.push(Segment { mode: Mode::FrameAcquire, start_ns: t, dur_ns: FRAME_ACQ_NS, power_uw: p_ret });
        energy_pj += p_ret * FRAME_ACQ_NS * 1e-3;
        t += FRAME_ACQ_NS;
        let p_inf = model.e_mem_inf_pj / model.latency_ns * 1e3 + p_ret;
        segs.push(Segment { mode: Mode::Inference, start_ns: t, dur_ns: model.latency_ns, power_uw: p_inf });
        energy_pj += model.e_mem_inf_pj + p_ret * model.latency_ns * 1e-3;
        t += model.latency_ns;
        // Idle until the next period tick.
        let idle_ns = (frame_start + period_ns - t).max(0.0);
        let (mode, p_idle) = if retains {
            (Mode::Retention, p_ret)
        } else {
            (Mode::PowerGated, 0.0)
        };
        segs.push(Segment { mode, start_ns: t, dur_ns: idle_ns, power_uw: p_idle });
        energy_pj += p_idle * idle_ns * 1e-3; // µW × ns → pJ (×1e-3)
        t = frame_start + period_ns.max(t - frame_start);
    }
    let avg_uw = energy_pj / t * 1e3; // pJ / ns → µW
    (segs, avg_uw)
}

/// Whether the pipeline meets the application's IPS_min with this model
/// (frame acquisition + wakeup + inference must fit in the period). The
/// wakeup term applies whenever the variant pays a wakeup energy — hybrid
/// P0 included, not just fully-gated P1.
pub fn meets_ips(model: &PowerModel, ips_min: f64) -> bool {
    let wakeup = if model.e_wakeup_pj > 0.0 { crate::mem::WAKEUP_NS } else { 0.0 };
    wakeup + FRAME_ACQ_NS + model.latency_ns <= 1e9 / ips_min
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{simba, MemFlavor, PeConfig};
    use crate::mapping::map_network;
    use crate::power::power_model;
    use crate::tech::{Device, Node};
    use crate::workload::builtin::detnet;

    fn model(flavor: MemFlavor) -> PowerModel {
        let arch = simba(PeConfig::V2);
        let net = detnet();
        let map = map_network(&arch, &net);
        power_model(&arch, &map, Node::N7, flavor, Device::VgsotMram)
    }

    #[test]
    fn timeline_modes_differ_sram_vs_nvm() {
        let (sram_segs, _) = simulate(&model(MemFlavor::SramOnly), 10.0, 3);
        let (nvm_segs, _) = simulate(&model(MemFlavor::P1), 10.0, 3);
        assert!(sram_segs.iter().any(|s| s.mode == Mode::Retention));
        assert!(!sram_segs.iter().any(|s| s.mode == Mode::Wakeup));
        assert!(nvm_segs.iter().any(|s| s.mode == Mode::PowerGated));
        assert!(nvm_segs.iter().any(|s| s.mode == Mode::Wakeup));
    }

    #[test]
    fn timeline_average_matches_closed_form() {
        // The simulated average power must converge to the analytical
        // P_mem(ips) — ties Fig 3 to Fig 5. P0 is included now that the
        // hybrid profile (wakeup + retained activation SRAM) is modeled.
        for flavor in MemFlavor::ALL {
            let m = model(flavor);
            let (_, avg) = simulate(&m, 10.0, 50);
            let closed = m.p_mem_uw(10.0);
            let rel = (avg - closed).abs() / closed.max(1e-9);
            assert!(rel < 0.02, "{flavor:?}: sim {avg} vs closed {closed}");
        }
    }

    #[test]
    fn p0_timeline_wakes_and_retains() {
        // The hybrid profile: wakeup segments (NVM weight macros) *and*
        // retention idle (activation SRAM) in the same timeline.
        let (segs, _) = simulate(&model(MemFlavor::P0), 10.0, 3);
        assert!(segs.iter().any(|s| s.mode == Mode::Wakeup));
        assert!(segs.iter().any(|s| s.mode == Mode::Retention));
        assert!(!segs.iter().any(|s| s.mode == Mode::PowerGated));
    }

    #[test]
    fn segments_tile_the_timeline() {
        let (segs, _) = simulate(&model(MemFlavor::P1), 20.0, 5);
        for w in segs.windows(2) {
            let end = w[0].start_ns + w[0].dur_ns;
            assert!((end - w[1].start_ns).abs() < 1.0, "gap at {end}");
        }
    }

    #[test]
    fn detnet_meets_its_ips_min() {
        // Table 3: DetNet IPS_min = 10 must be satisfiable on Simba (P0/P1).
        for flavor in [MemFlavor::P0, MemFlavor::P1] {
            assert!(meets_ips(&model(flavor), 10.0), "{flavor:?}");
        }
    }
}
