#!/usr/bin/env python3
"""CI bench-regression harness (stdlib only).

Merges the per-binary JSON records the rust benches emit (via
``XR_DSE_BENCH_JSON=<path> cargo bench --bench <name>``) into one
``BENCH_5.json`` trajectory file, then gates the measured wall times
against the checked-in ``benches/baseline.json``:

- a bench whose measured ``mean_s`` exceeds ``baseline * (1 + tolerance)``
  is a **regression** → exit 1;
- a baseline bench missing from the results is **lost coverage** → exit 1;
- a bench more than ``tolerance`` *faster* than baseline is reported as a
  stale-baseline warning (never fails — machine variance only hurts one
  way);
- benches present in the results but absent from the baseline are listed
  as unbaselined (they start being gated once added to baseline.json).

Refreshing the baseline: download a green run's ``BENCH_5.json`` artifact
(or produce one locally with the same pinned ``XR_DSE_THREADS``) and run
``python3 ci/bench_regression.py --refresh BENCH_5.json`` to rewrite
``benches/baseline.json`` from it. See DESIGN.md §CI bench-regression.
"""

import argparse
import json
import sys


def load_records(paths):
    """Merge the `benches` arrays of the input files, in input order."""
    merged = []
    for path in paths:
        with open(path) as f:
            doc = json.load(f)
        benches = doc.get("benches", [])
        if not benches:
            print(f"error: {path} contains no bench records", file=sys.stderr)
            sys.exit(1)
        merged.extend(benches)
    return merged


def write_trajectory(out_path, records):
    doc = {
        "schema": "xr-edge-dse bench trajectory v1",
        "benches": records,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path} ({len(records)} benches)")


def compare(records, baseline_path):
    with open(baseline_path) as f:
        baseline = json.load(f)
    tolerance = float(baseline.get("tolerance", 0.25))
    expected = baseline.get("benches", {})
    measured = {r["name"]: r for r in records}

    regressions, missing, stale, unbaselined = [], [], [], []
    width = max((len(n) for n in set(expected) | set(measured)), default=4)
    print(f"\nbench gate (tolerance ±{tolerance:.0%} vs {baseline_path}):")
    for name, base in sorted(expected.items()):
        base_mean = float(base["mean_s"])
        rec = measured.get(name)
        if rec is None:
            missing.append(name)
            print(f"  {name:<{width}}  MISSING (baseline {base_mean:.4f}s)")
            continue
        mean = float(rec["mean_s"])
        ratio = mean / base_mean if base_mean > 0 else float("inf")
        ups = rec.get("units_per_s")
        thru = f"  {ups:,.0f} units/s" if ups else ""
        verdict = "ok"
        if ratio > 1.0 + tolerance:
            verdict = "REGRESSION"
            regressions.append((name, mean, base_mean))
        elif ratio < 1.0 - tolerance:
            verdict = "faster-than-baseline (stale?)"
            stale.append(name)
        print(
            f"  {name:<{width}}  {mean:.4f}s vs {base_mean:.4f}s "
            f"({ratio:.0%} of baseline)  {verdict}{thru}"
        )
    for name in sorted(set(measured) - set(expected)):
        unbaselined.append(name)
        print(f"  {name:<{width}}  {measured[name]['mean_s']:.4f}s  (not in baseline)")

    if stale:
        print(f"note: {len(stale)} bench(es) far below baseline — consider refreshing it")
    if unbaselined:
        print(f"note: {len(unbaselined)} bench(es) not gated yet — add them to the baseline")
    if missing:
        print(f"FAIL: {len(missing)} baseline bench(es) missing from the results", file=sys.stderr)
    for name, mean, base_mean in regressions:
        print(
            f"FAIL: {name} regressed: {mean:.4f}s vs baseline {base_mean:.4f}s "
            f"(+{(mean / base_mean - 1.0):.0%}, tolerance {tolerance:.0%})",
            file=sys.stderr,
        )
    return not (regressions or missing)


def refresh(baseline_path, trajectory_path, tolerance):
    with open(trajectory_path) as f:
        doc = json.load(f)
    benches = {
        r["name"]: {"mean_s": r["mean_s"]}
        for r in doc.get("benches", [])
        # only gate the model-evaluation benches; artifact-dependent ones
        # (PJRT, workload-JSON parse) are machine-local extras
        if not r["name"].startswith(("L3c", "util"))
    }
    out = {"tolerance": tolerance, "benches": benches}
    with open(baseline_path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"rewrote {baseline_path} from {trajectory_path} ({len(benches)} benches)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("inputs", nargs="*", help="per-binary bench JSON files to merge")
    ap.add_argument("--out", default="BENCH_5.json", help="merged trajectory output")
    ap.add_argument("--baseline", default="benches/baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.25, help="used with --refresh")
    ap.add_argument(
        "--refresh",
        metavar="TRAJECTORY",
        help="rewrite --baseline from an existing trajectory file and exit",
    )
    args = ap.parse_args()

    if args.refresh:
        refresh(args.baseline, args.refresh, args.tolerance)
        return

    if not args.inputs:
        ap.error("no input bench JSON files given")
    records = load_records(args.inputs)
    write_trajectory(args.out, records)
    if not compare(records, args.baseline):
        sys.exit(1)


if __name__ == "__main__":
    main()
