"""Training (Fig 1(f) reproduction): DetNet with AdamW on circle + label
losses; EDSNet with Adam on DiceLoss — the paper's optimizers and loss
functions (§2.2), on the synthetic FPHAB/OpenEDS stand-ins, scaled down to
a build-time budget (the paper trained 300 epochs / 6 epochs on real data;
we train a few hundred steps — the qualitative claim reproduced is the
loss-curve *shape*: circle-MSE dropping orders of magnitude, Dice
converging within a fraction of the schedule).

Usage: python -m compile.train --net detnet --steps 200 --out ../artifacts
Writes <out>/loss_curves.json (merged across nets) and
<out>/<net>.params.npz.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model as M


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adamw_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, wd=1e-4):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)

    def upd(p, m, v):
        return p - lr * (m * mhat_scale / (jnp.sqrt(v * vhat_scale) + eps) + wd * p)

    return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "t": t}


def train_detnet(steps=200, batch=16, seed=0, log_every=10):
    spec = M.detnet_spec()
    params = M.init_params(spec, jax.random.PRNGKey(seed))
    state = adamw_init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, state, x, c, r, y):
        def loss_fn(p):
            logits = M.forward(spec, p, x, use_pallas=False)
            circle, ce = M.detnet_loss(logits, c, r, y)
            return circle + 0.1 * ce, (circle, ce)

        (loss, (circle, ce)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, state = adamw_step(params, grads, state)  # AdamW (§2.2)
        return params, state, loss, circle, ce

    curve = []
    for i in range(steps):
        frames, centers, radii, labels = data.hand_batch(batch, rng)
        params, state, loss, circle, ce = step(
            params, state, jnp.asarray(frames), jnp.asarray(centers),
            jnp.asarray(radii), jnp.asarray(labels)
        )
        if i % log_every == 0 or i == steps - 1:
            curve.append(
                dict(step=i, loss=float(loss), circle=float(circle), label=float(ce))
            )
    return spec, params, curve


def train_edsnet(steps=60, batch=4, seed=0, log_every=5):
    spec = M.edsnet_spec()
    params = M.init_params(spec, jax.random.PRNGKey(seed))
    state = adamw_init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, state, x, m1h):
        def loss_fn(p):
            logits = M.forward(spec, p, x, use_pallas=False)
            return M.dice_loss(logits, m1h)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        # Adam == AdamW with wd=0 (§2.2 uses Adam for EDSNet)
        params, state = adamw_step(params, grads, state, wd=0.0)
        return params, state, loss

    curve = []
    for i in range(steps):
        frames, masks = data.eye_batch(batch, rng)
        params, state, loss = step(
            params, state, jnp.asarray(frames), jnp.asarray(data.onehot_mask(masks))
        )
        if i % log_every == 0 or i == steps - 1:
            curve.append(dict(step=i, dice=float(loss)))
    return spec, params, curve


def save_params(params, path):
    flat = {}
    for name, p in params.items():
        flat[f"{name}.w"] = np.asarray(p["w"])
        flat[f"{name}.b"] = np.asarray(p["b"])
    np.savez(path, **flat)


def load_params(path):
    flat = np.load(path)
    params = {}
    for key in flat.files:
        name, kind = key.rsplit(".", 1)
        params.setdefault(name, {})[kind] = jnp.asarray(flat[key])
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", choices=["detnet", "edsnet", "both"], default="both")
    ap.add_argument("--steps", type=int, default=0, help="0 = per-net default")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    curves_path = os.path.join(args.out, "loss_curves.json")
    curves = {}
    if os.path.exists(curves_path):
        curves = json.load(open(curves_path))

    if args.net in ("detnet", "both"):
        spec, params, curve = train_detnet(steps=args.steps or 200)
        save_params(params, os.path.join(args.out, "detnet.params.npz"))
        curves["detnet"] = curve
        print(f"detnet: circle loss {curve[0]['circle']:.4f} -> {curve[-1]['circle']:.6f}")
    if args.net in ("edsnet", "both"):
        spec, params, curve = train_edsnet(steps=args.steps or 60)
        save_params(params, os.path.join(args.out, "edsnet.params.npz"))
        curves["edsnet"] = curve
        print(f"edsnet: dice loss {curve[0]['dice']:.4f} -> {curve[-1]['dice']:.4f}")

    json.dump(curves, open(curves_path, "w"), indent=1)
    print(f"wrote {curves_path}")


if __name__ == "__main__":
    main()
