"""L2: DetNet and EDSNet in JAX, calling the L1 Pallas kernels.

The layer topology here is the single source of truth shared with the rust
analytical models: ``export_workload()`` emits the same JSON schema that
``rust/src/workload`` loads, and an integration test asserts the rust
built-in definitions agree (total MACs / weights equal).

Networks (paper §2.2, Fig 1(d)/(e)):
- **DetNet** — MobileNetV2-style feature extractor + three regression heads
  (bounding-circle center, radius, left/right label) on 1×128×128 frames.
- **EDSNet** — UNet decoder over a MobileNetV2 encoder, 4-class mask on
  1×192×320 eye crops.
"""

import math

import jax
import jax.numpy as jnp

from .kernels import conv as K
from .kernels import ref as R


# ---------------------------------------------------------------------------
# Layer-spec IR (mirrors rust/src/workload): every layer is a dict. Control
# flow (residual sources, skip taps) is resolved at *build* time and stored
# as layer indices, so the forward pass is a single linear sweep.
# ---------------------------------------------------------------------------


class SpecBuilder:
    """Shape-propagating builder — the python twin of rust's NetBuilder."""

    def __init__(self, name, c, h, w):
        self.name = name
        self.input = (c, h, w)
        self.cur = (c, h, w)
        self.layers = []
        self.skip_tap = {}  # tag -> layer index whose output is the skip

    def _push(self, kind, out, **extra):
        c, h, w = self.cur
        oc, oh, ow = out
        self.layers.append(
            dict(
                name=f"{kind}{len(self.layers)}",
                kind=kind,
                in_c=c, in_h=h, in_w=w,
                out_c=oc, out_h=oh, out_w=ow,
                **extra,
            )
        )
        self.cur = out
        return self

    def conv(self, out_c, k, stride):
        pad = k // 2
        _, h, w = self.cur
        oh = (h + 2 * pad - k) // stride + 1
        ow = (w + 2 * pad - k) // stride + 1
        return self._push("conv", (out_c, oh, ow), kh=k, kw=k, stride=stride,
                          pad=pad, groups=1)

    def pw(self, out_c):
        return self.conv(out_c, 1, 1)

    def dw(self, k, stride):
        pad = k // 2
        c, h, w = self.cur
        oh = (h + 2 * pad - k) // stride + 1
        ow = (w + 2 * pad - k) // stride + 1
        return self._push("dwconv", (c, oh, ow), kh=k, kw=k, stride=stride,
                          pad=pad, groups=c)

    def irb(self, out_c, expand, stride):
        c = self.cur[0]
        residual = stride == 1 and c == out_c
        block_start = len(self.layers)
        if expand > 1:
            self.pw(c * expand)
        self.dw(3, stride)
        self.pw(out_c)
        if residual:
            oc, oh, ow = self.cur
            # 'src' = index of the block's first layer; its *input* is the
            # residual operand.
            self._push("add", (oc, oh, ow), src=block_start)
        return self

    def gap(self):
        c, h, _ = self.cur
        return self._push("avgpool", (c, 1, 1), k=h, stride=h)

    def upsample(self, factor):
        c, h, w = self.cur
        return self._push("upsample", (c, h * factor, w * factor), factor=factor)

    def save_skip(self, tag):
        self.skip_tap[tag] = len(self.layers) - 1
        return self

    def concat_skip(self, tag):
        tap = self.skip_tap[tag]
        t = self.layers[tap]
        sc, sh, sw = t["out_c"], t["out_h"], t["out_w"]
        c, h, w = self.cur
        assert (sh, sw) == (h, w), f"skip {tag} spatial mismatch"
        self.cur = (c + sc, h, w)
        return self._push("concat", (c + sc, h, w), tap=tap)

    def linear(self, out):
        c, h, w = self.cur
        feat = c * h * w
        self.layers.append(
            dict(name=f"fc{len(self.layers)}", kind="linear",
                 in_c=feat, in_h=1, in_w=1, out_c=out, out_h=1, out_w=1)
        )
        self.cur = (out, 1, 1)
        return self


def detnet_spec():
    b = SpecBuilder("detnet", 1, 128, 128)
    b.conv(8, 3, 2)
    b.irb(8, 1, 1)
    b.irb(16, 6, 2)
    b.irb(16, 6, 1)
    b.irb(24, 6, 2)
    b.irb(24, 6, 1)
    b.irb(40, 6, 2)
    b.irb(40, 6, 1)
    b.irb(80, 4, 2)
    b.pw(128)
    b.gap()
    b.linear(64)
    b.linear(4 + 2 + 2)
    return b


def edsnet_spec():
    b = SpecBuilder("edsnet", 1, 192, 320)
    b.conv(16, 3, 2)
    b.save_skip("s1")
    b.irb(24, 6, 2)
    b.irb(24, 6, 1)
    b.save_skip("s2")
    b.irb(32, 6, 2)
    b.irb(32, 6, 1)
    b.save_skip("s3")
    b.irb(64, 6, 2)
    b.irb(64, 6, 1)
    b.irb(96, 6, 1)
    # UNet decoder (two 3×3 convs per stage, as in [12])
    b.upsample(2)
    b.concat_skip("s3")
    b.pw(128)
    b.conv(128, 3, 1)
    b.upsample(2)
    b.concat_skip("s2")
    b.pw(64)
    b.conv(64, 3, 1)
    b.conv(64, 3, 1)
    b.upsample(2)
    b.concat_skip("s1")
    b.pw(32)
    b.conv(32, 3, 1)
    b.conv(32, 3, 1)
    b.conv(16, 3, 1)
    b.upsample(2)
    b.conv(8, 3, 1)
    b.pw(4)
    return b


def spec_by_name(name: str) -> "SpecBuilder":
    return {"detnet": detnet_spec, "edsnet": edsnet_spec}[name]()


def export_workload(spec: SpecBuilder) -> dict:
    """The JSON schema rust/src/workload::Network::from_json loads."""
    drop = {"src", "tap"}
    layers = [{k: v for k, v in l.items() if k not in drop} for l in spec.layers]
    return dict(name=spec.name, input=list(spec.input), layers=layers)


def total_macs(spec: SpecBuilder) -> int:
    """True MACs (conv/linear) — must agree with rust Network::true_macs."""
    total = 0
    for l in spec.layers:
        if l["kind"] in ("conv", "dwconv"):
            cpg = l["in_c"] // l["groups"]
            total += l["out_c"] * l["out_h"] * l["out_w"] * cpg * l["kh"] * l["kw"]
        elif l["kind"] == "linear":
            total += l["in_c"] * l["out_c"]
    return total


def total_weights(spec: SpecBuilder) -> int:
    total = 0
    for l in spec.layers:
        if l["kind"] in ("conv", "dwconv"):
            total += (l["in_c"] // l["groups"]) * l["kh"] * l["kw"] * l["out_c"]
        elif l["kind"] == "linear":
            total += l["in_c"] * l["out_c"]
    return total


# ---------------------------------------------------------------------------
# Parameters & forward pass.
# ---------------------------------------------------------------------------


def init_params(spec: SpecBuilder, key) -> dict:
    """He-initialized parameters, keyed by layer name."""
    params = {}
    for l in spec.layers:
        if l["kind"] in ("conv", "dwconv"):
            fan_in = (l["in_c"] // l["groups"]) * l["kh"] * l["kw"]
            shape = (l["out_c"], l["in_c"] // l["groups"], l["kh"], l["kw"])
        elif l["kind"] == "linear":
            fan_in = l["in_c"]
            shape = (l["in_c"], l["out_c"])
        else:
            continue
        key, sub = jax.random.split(key)
        params[l["name"]] = {
            "w": jax.random.normal(sub, shape, jnp.float32) * math.sqrt(2.0 / fan_in),
            "b": jnp.zeros((l["out_c"],), jnp.float32),
        }
    return params


def forward(spec: SpecBuilder, params: dict, x, use_pallas: bool = True):
    """Run the network on `x` (N, C, H, W) float32.

    `use_pallas=True` routes MXU-shaped convolutions through the L1 Pallas
    im2col-GEMM kernel (interpret mode) so the AOT artifact contains the
    kernel lowering. **Kernel-dispatch policy (§Perf iterations 4–6,
    measured on the rust/PJRT serving path):** dense convs take the Pallas
    GEMM when the contraction is MXU-shaped (out_c ≥ 64, C·KH·KW ≥ 32);
    the giant-M/narrow-N decoder tails go native. Depthwise convs take the
    plane-blocked Pallas kernel only for small planes (≤64×64) — it beats
    the backend's grouped conv there (DetNet 28→7 ms) but its interpret
    lowering explodes on EDSNet's 96×160+ planes (18.8 s → 0.86 s after
    dispatch). The full-Pallas depthwise/IRB kernels remain the documented
    TPU mapping, tested against ref in test_kernels.py.

    `use_pallas=False` uses the pure-jnp reference path everywhere
    (training speed). Both paths are numerically cross-checked in
    python/tests/test_model.py.
    """
    # Dense-conv dispatch: the Pallas im2col GEMM wins whenever the GEMM is
    # MXU-shaped (N = out_c and K = C·KH·KW both ≥32); giant-M/narrow-N
    # decoder tails (EDSNet's 16/8/4-channel full-resolution convs) thrash
    # the grid machinery under interpret lowering and go native.
    def conv(h, w, stride, pad):
        n_dim = w.shape[0]
        k_dim = w.shape[1] * w.shape[2] * w.shape[3]
        if use_pallas and n_dim >= 64 and k_dim >= 32:
            return K.conv2d(h, w, stride=stride, pad=pad)
        return R.conv2d_ref(h, w, stride=stride, pad=pad)

    # Depthwise dispatch by plane size: the plane-blocked Pallas kernel
    # keeps (c_block × H × W) resident per grid step — fine for DetNet's
    # ≤64×64 planes (and faster than the backend's native grouped conv
    # there: 28 ms → 7 ms measured), but the interpret lowering of the
    # kh×kw shifted-slice loop on EDSNet's 96×160+ planes explodes
    # (18.8 s/inf). Threshold at 64×64 elements.
    def dwconv(h, w, stride, pad):
        if use_pallas and h.shape[2] * h.shape[3] <= 64 * 64:
            return K.depthwise_conv2d(h, w, stride=stride, pad=pad)
        return R.depthwise_conv2d_ref(h, w, stride=stride, pad=pad)

    inputs = []  # inputs[i] = input tensor of layer i
    outputs = []  # outputs[i] = output tensor of layer i
    h = x
    last = len(spec.layers) - 1
    for i, l in enumerate(spec.layers):
        inputs.append(h)
        kind = l["kind"]
        if kind in ("conv", "dwconv"):
            p = params[l["name"]]
            f = dwconv if kind == "dwconv" else conv
            h = f(h, p["w"], stride=l["stride"], pad=l["pad"])
            h = h + p["b"][None, :, None, None]
            # ReLU6 everywhere except IRB projections (linear bottleneck,
            # MobileNetV2) and the final head.
            is_projection = (
                kind == "conv"
                and l["kh"] == 1
                and i + 1 <= last
                and i >= 1
                and spec.layers[i - 1]["kind"] == "dwconv"
            )
            if i != last and not is_projection:
                h = jnp.clip(h, 0.0, 6.0)
        elif kind == "add":
            h = h + inputs[l["src"]]
        elif kind == "avgpool":
            h = jnp.mean(h, axis=(2, 3), keepdims=True)
        elif kind == "upsample":
            f = l["factor"]
            h = jnp.repeat(jnp.repeat(h, f, axis=2), f, axis=3)
        elif kind == "concat":
            h = jnp.concatenate([h, outputs[l["tap"]]], axis=1)
        elif kind == "linear":
            p = params[l["name"]]
            h = h.reshape(h.shape[0], -1) @ p["w"] + p["b"]
            if i != last:
                h = jnp.clip(h, 0.0, 6.0)
        else:
            raise ValueError(f"unknown kind {kind}")
        outputs.append(h)
    return h


# ---------------------------------------------------------------------------
# Task heads / losses (§2.2).
# ---------------------------------------------------------------------------


def detnet_outputs(logits):
    """Split the 8-wide head: centers (2 hands × x,y), radii (2), label
    logits (2 = left/right)."""
    centers = jax.nn.sigmoid(logits[:, 0:4])
    radii = jax.nn.sigmoid(logits[:, 4:6]) * 0.5
    label = logits[:, 6:8]
    return centers, radii, label


def detnet_loss(logits, truth_center, truth_radius, truth_label):
    """Circle loss (weighted center+radius MSE, center weighted higher) +
    label cross-entropy — §2.2's two loss components."""
    centers, radii, label = detnet_outputs(logits)
    center_mse = jnp.mean((centers - truth_center) ** 2)
    radius_mse = jnp.mean((radii - truth_radius) ** 2)
    circle = 0.8 * center_mse + 0.2 * radius_mse
    logp = jax.nn.log_softmax(label, axis=-1)
    ce = -jnp.mean(jnp.sum(truth_label * logp, axis=-1))
    return circle, ce


def dice_loss(logits, mask_onehot, eps=1e-6):
    """Smoothed DiceLoss over the 4-class segmentation output (§2.2,
    EDSNet). The smoothing term makes absent classes score 1 (no penalty)
    instead of 0, the standard segmentation-models convention [20]."""
    probs = jax.nn.softmax(logits, axis=1)
    num = 2.0 * jnp.sum(probs * mask_onehot, axis=(0, 2, 3)) + eps
    den = jnp.sum(probs + mask_onehot, axis=(0, 2, 3)) + eps
    return 1.0 - jnp.mean(num / den)


def iou(pred_classes, truth_classes, n_classes=4):
    """Mean intersection-over-union (eye-segmentation accuracy metric)."""
    ious = []
    for c in range(n_classes):
        p = pred_classes == c
        t = truth_classes == c
        inter = jnp.sum(p & t)
        union = jnp.sum(p | t)
        ious.append(jnp.where(union > 0, inter / union, 1.0))
    return float(jnp.mean(jnp.stack(ious)))
