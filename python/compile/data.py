"""Synthetic dataset generators — the documented substitution for FPHAB
(hand detection) and OpenEDS (eye segmentation); see DESIGN.md
§Substitutions. Mirrors `rust/src/coordinator/sensor.rs` so the serving
path sees in-distribution frames.

Hand frames: dark background + 1–2 bright soft-edged blobs; the annotation
is the bounding circle (center, radius) and the handedness label — exactly
the keypoint→circle conversion the paper performs on FPHAB (§2.2: center =
mean of keypoints, radius = max distance to center).

Eye frames: concentric sclera/iris/pupil ellipses with a 4-class mask
(background / sclera / iris / pupil), OpenEDS-style.
"""

import numpy as np

HAND_SHAPE = (1, 128, 128)
EYE_SHAPE = (1, 192, 320)
EYE_CLASSES = 4


def _soft_disc(img, cx, cy, r, value):
    h, w = img.shape
    yy, xx = np.mgrid[0:h, 0:w]
    d2 = (xx - cx * w) ** 2 + (yy - cy * h) ** 2
    r_pix = r * min(h, w)
    mask = d2 < r_pix**2
    t = np.clip(1.0 - d2 / max(r_pix**2, 1e-9), 0.0, 1.0)
    img[mask] = (value * (0.5 + 0.5 * t))[mask]
    return mask


def hand_batch(n, rng: np.random.Generator):
    """Returns (frames [n,1,128,128], centers [n,4], radii [n,2],
    labels [n,2] one-hot L/R). Second hand present with p=0.35; absent hand
    repeats the first (the loss learns to track what exists)."""
    c, h, w = HAND_SHAPE
    frames = np.full((n, c, h, w), 0.05, np.float32)
    centers = np.zeros((n, 4), np.float32)
    radii = np.zeros((n, 2), np.float32)
    labels = np.zeros((n, 2), np.float32)
    for i in range(n):
        # 21 synthetic keypoints → circle, like the FPHAB conversion.
        kx = rng.uniform(0.25, 0.75)
        ky = rng.uniform(0.25, 0.75)
        spread = rng.uniform(0.05, 0.18)
        kps = rng.normal([kx, ky], spread, size=(21, 2)).clip(0.02, 0.98)
        cxy = kps.mean(axis=0)
        r = float(np.linalg.norm(kps - cxy, axis=1).max())
        _soft_disc(frames[i, 0], cxy[0], cxy[1], r, 0.9)
        is_left = rng.random() < 0.5
        # left hands are rendered slightly darker — a learnable cue
        if is_left:
            frames[i, 0] *= 0.8
        centers[i] = [cxy[0], cxy[1], cxy[0], cxy[1]]
        radii[i] = [r, r]
        labels[i] = [1.0, 0.0] if is_left else [0.0, 1.0]
        frames[i, 0] += rng.normal(0, 0.01, (h, w)).astype(np.float32)
    return frames.clip(0, 1), centers, radii, labels


def eye_batch(n, rng: np.random.Generator):
    """Returns (frames [n,1,192,320], masks [n,192,320] int class ids)."""
    c, h, w = EYE_SHAPE
    frames = np.full((n, c, h, w), 0.1, np.float32)
    masks = np.zeros((n, h, w), np.int32)
    for i in range(n):
        cx = rng.uniform(0.35, 0.65)
        cy = rng.uniform(0.35, 0.65)
        r_iris = rng.uniform(0.10, 0.18)
        r_pupil = r_iris * rng.uniform(0.3, 0.6)
        r_sclera = r_iris * rng.uniform(1.8, 2.4)
        m = _soft_disc(frames[i, 0], cx, cy, r_sclera, 0.55)
        masks[i][m] = 1
        m = _soft_disc(frames[i, 0], cx, cy, r_iris, 0.75)
        masks[i][m] = 2
        m = _soft_disc(frames[i, 0], cx, cy, r_pupil, 0.12)
        masks[i][m] = 3
        frames[i, 0] += rng.normal(0, 0.01, (h, w)).astype(np.float32)
    return frames.clip(0, 1), masks


def onehot_mask(masks, n_classes=EYE_CLASSES):
    """[n,h,w] int → [n,c,h,w] float one-hot."""
    n, h, w = masks.shape
    out = np.zeros((n, n_classes, h, w), np.float32)
    for cls in range(n_classes):
        out[:, cls] = masks == cls
    return out
