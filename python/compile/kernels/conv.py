"""L1 Pallas kernels: the compute hot-spots of DetNet/EDSNet.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets
systolic ASICs, so the TPU mapping of its insight is (i) conv → im2col →
MXU-shaped matmul tiles sized for VMEM, and (ii) the IRB's
"never materialize the expanded tensor" property expressed by fusing
expand→depthwise→project inside one ``pallas_call`` so the expanded
activation only ever lives in VMEM scratch.

All kernels run with ``interpret=True``: the CPU PJRT plugin (and therefore
the rust runtime) cannot execute Mosaic custom-calls; real-TPU efficiency is
estimated structurally in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile edge. Shapes are padded up to multiples of this so the
# systolic array would be fully fed on real hardware.
TILE = 128


def _pad_to(x, multiple, axis):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Tiled matmul — the GEMM core used by the im2col convolution.
# ---------------------------------------------------------------------------


def _matmul_kernel(a_ref, b_ref, o_ref):
    """Grid (M/T, N/T, K/T): the output tile (indexed independently of k)
    stays resident in VMEM across the K loop — initialize on the first K
    step, then accumulate an MXU-shaped `a_tile @ b_tile` per step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def _dim_tile(d: int, cap: int) -> int:
    """Per-dimension tile: the smallest power of two ≥ d, capped at `cap`
    (MXU edge). §Perf iteration 3: blanket 128-padding wastes >90% of the
    MXU work on ≤64-channel layers (DetNet's K = C·KH·KW is 9–360); a
    shape-adaptive tile keeps the grid dense while staying MXU-aligned for
    the ≥128-wide EDSNet decoder GEMMs."""
    t = 8
    while t < d and t < cap:
        t *= 2
    return min(t, cap)


def matmul(a, b, tile: int = TILE, interpret: bool = True):
    """Tiled matmul: (M,K) @ (K,N) → (M,N) with shape-adaptive VMEM tiles
    (≤ `tile` per edge). VMEM per grid step = 3 tiles ≤ 3·128²·4 B = 192 kB,
    comfortably inside a 16 MiB VMEM budget with double-buffering room."""
    m0, k0 = a.shape
    k0b, n0 = b.shape
    assert k0 == k0b, f"inner dims {k0} != {k0b}"
    tm, tk, tn = _dim_tile(m0, tile), _dim_tile(k0, tile), _dim_tile(n0, tile)
    a = _pad_to(_pad_to(a, tm, 0), tk, 1)
    b = _pad_to(_pad_to(b, tk, 0), tn, 1)
    m, k = a.shape
    n = b.shape[1]
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(m // tm, n // tn, k // tk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:m0, :n0]


# ---------------------------------------------------------------------------
# im2col convolution built on the tiled matmul.
# ---------------------------------------------------------------------------


def conv2d(x, w, stride: int = 1, pad: int = 0, interpret: bool = True):
    """NCHW conv via im2col + Pallas matmul. x: (N,C,H,W), w: (O,I,KH,KW)."""
    n, c, h, ww = x.shape
    o, i, kh, kw = w.shape
    assert c == i, f"channels {c} != {i}"
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (ww + 2 * pad - kw) // stride + 1
    # im2col: patches (N·OH·OW, C·KH·KW)
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=[(0, 0), (0, 0)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (N, C*KH*KW, OH, OW)
    cols = patches.transpose(0, 2, 3, 1).reshape(n * oh * ow, c * kh * kw)
    wmat = w.reshape(o, c * kh * kw).T  # (C·KH·KW, O)
    out = matmul(cols, wmat, interpret=interpret)  # (N·OH·OW, O)
    return out.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)


# ---------------------------------------------------------------------------
# Depthwise conv kernel: one channel-block per grid step, H×W plane in VMEM
# (the Eyeriss-spad analogue: the filter row stays resident while the plane
# streams through).
# ---------------------------------------------------------------------------


def _dw_kernel(x_ref, w_ref, o_ref, *, kh, kw, stride, oh, ow):
    x = x_ref[...]  # (CB, H+2p, W+2p) padded plane block
    w = w_ref[...]  # (CB, KH, KW)
    acc = jnp.zeros((x.shape[0], oh, ow), jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            window = jax.lax.slice(
                x,
                (0, dy, dx),
                (x.shape[0], dy + (oh - 1) * stride + 1, dx + (ow - 1) * stride + 1),
                (1, stride, stride),
            )
            acc += window * w[:, dy : dy + 1, dx : dx + 1]
    o_ref[...] = acc


def depthwise_conv2d(x, w, stride: int = 1, pad: int = 0, c_block: int = 8,
                     interpret: bool = True):
    """Depthwise NCHW conv. x: (N,C,H,W), w: (C,1,KH,KW)."""
    n, c, h, ww = x.shape
    cw, _, kh, kw = w.shape
    assert c == cw
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    hp, wp = x.shape[2], x.shape[3]
    oh = (hp - kh) // stride + 1
    ow = (wp - kw) // stride + 1
    cb = min(c_block, c)
    cpad = (-c) % cb
    if cpad:
        x = jnp.pad(x, ((0, 0), (0, cpad), (0, 0), (0, 0)))
        w = jnp.pad(w, ((0, cpad), (0, 0), (0, 0), (0, 0)))
    ct = x.shape[1]
    w2 = w.reshape(ct, kh, kw)

    def per_image(xi):
        return pl.pallas_call(
            functools.partial(_dw_kernel, kh=kh, kw=kw, stride=stride, oh=oh, ow=ow),
            grid=(ct // cb,),
            in_specs=[
                pl.BlockSpec((cb, hp, wp), lambda i: (i, 0, 0)),
                pl.BlockSpec((cb, kh, kw), lambda i: (i, 0, 0)),
            ],
            out_specs=pl.BlockSpec((cb, oh, ow), lambda i: (i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((ct, oh, ow), jnp.float32),
            interpret=interpret,
        )(xi, w2)

    out = jax.vmap(per_image)(x)
    return out[:, :c]


# ---------------------------------------------------------------------------
# Fused IRB: expand (1x1) → ReLU6 → depthwise 3x3 → ReLU6 → project (1x1).
# The expanded tensor lives only in kernel-local values (VMEM under a real
# TPU lowering) — the paper's IRB memory-footprint insight.
# ---------------------------------------------------------------------------


def _irb_kernel(x_ref, we_ref, wd_ref, wp_ref, o_ref, *, stride, oh, ow, kh, kw):
    x = x_ref[...]  # (C, H+2, W+2) padded input plane
    we = we_ref[...]  # (E, C)
    wd = wd_ref[...]  # (E, KH, KW)
    wp = wp_ref[...]  # (O, E)
    c, hp, wp_ = x.shape
    # expand: (E, H+2, W+2) — never leaves the kernel.
    h = jnp.tensordot(we, x.reshape(c, hp * wp_), axes=1).reshape(-1, hp, wp_)
    h = jnp.clip(h, 0.0, 6.0)
    # depthwise
    acc = jnp.zeros((h.shape[0], oh, ow), jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            window = jax.lax.slice(
                h,
                (0, dy, dx),
                (h.shape[0], dy + (oh - 1) * stride + 1, dx + (ow - 1) * stride + 1),
                (1, stride, stride),
            )
            acc += window * wd[:, dy : dy + 1, dx : dx + 1]
    acc = jnp.clip(acc, 0.0, 6.0)
    # project: (O, OH, OW)
    e = acc.shape[0]
    y = jnp.tensordot(wp, acc.reshape(e, oh * ow), axes=1).reshape(-1, oh, ow)
    o_ref[...] = y


def irb(x, w_expand, w_dw, w_project, stride: int = 1, interpret: bool = True):
    """Fused inverted-residual bottleneck. x: (N,C,H,W);
    w_expand: (E,C,1,1); w_dw: (E,1,3,3); w_project: (O,E,1,1)."""
    n, c, h, w = x.shape
    e = w_expand.shape[0]
    o = w_project.shape[0]
    kh, kw = w_dw.shape[2], w_dw.shape[3]
    pad = kh // 2
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    hp, wp_ = xp.shape[2], xp.shape[3]
    oh = (hp - kh) // stride + 1
    ow = (wp_ - kw) // stride + 1
    we = w_expand.reshape(e, c)
    wd = w_dw.reshape(e, kh, kw)
    wpm = w_project.reshape(o, e)

    def per_image(xi):
        return pl.pallas_call(
            functools.partial(_irb_kernel, stride=stride, oh=oh, ow=ow, kh=kh, kw=kw),
            grid=(1,),
            in_specs=[
                pl.BlockSpec((c, hp, wp_), lambda i: (0, 0, 0)),
                pl.BlockSpec((e, c), lambda i: (0, 0)),
                pl.BlockSpec((e, kh, kw), lambda i: (0, 0, 0)),
                pl.BlockSpec((o, e), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((o, oh, ow), lambda i: (0, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((o, oh, ow), jnp.float32),
            interpret=interpret,
        )(xi, we, wd, wpm)

    y = jax.vmap(per_image)(xp)
    if stride == 1 and y.shape == x.shape:
        y = y + x
    return y
