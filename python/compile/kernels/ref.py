"""Pure-jnp reference oracle for the Pallas kernels (L1 correctness signal).

Every kernel in this package has a reference implementation here written
with plain ``jax.numpy`` / ``lax`` ops. ``python/tests/test_kernels.py``
sweeps shapes and dtypes (hypothesis) and asserts allclose between kernel
and oracle under ``interpret=True``.
"""

import jax
import jax.numpy as jnp


def conv2d_ref(x, w, stride: int = 1, pad: int = 0):
    """NCHW conv2d, OIHW weights, no groups."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def depthwise_conv2d_ref(x, w, stride: int = 1, pad: int = 0):
    """Depthwise NCHW conv2d; w has shape (C, 1, KH, KW)."""
    c = x.shape[1]
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=c,
    )


def matmul_ref(a, b):
    return jnp.matmul(a, b)


def irb_ref(x, w_expand, w_dw, w_project, stride: int = 1):
    """Inverted residual bottleneck (Fig 1(c)): 1x1 expand + ReLU6 →
    3x3 depthwise (stride) + ReLU6 → 1x1 project (linear), residual when
    shapes allow. The paper's point: the expanded tensor never needs to be
    materialized in main memory — the fused Pallas kernel keeps it in VMEM.
    """
    h = conv2d_ref(x, w_expand)
    h = jnp.clip(h, 0.0, 6.0)
    h = depthwise_conv2d_ref(h, w_dw, stride=stride, pad=1)
    h = jnp.clip(h, 0.0, 6.0)
    y = conv2d_ref(h, w_project)
    if stride == 1 and y.shape == x.shape:
        y = y + x
    return y


def fake_quant_ref(x, scale, zero, qmin=-128, qmax=127):
    """Per-tensor affine fake-quantization (TensorRT-style PTQ arithmetic)."""
    q = jnp.round(x / scale) + zero
    q = jnp.clip(q, qmin, qmax)
    return (q - zero) * scale
