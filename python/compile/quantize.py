"""Post-training quantization (§2.2): per-tensor affine INT8, the TensorRT
recipe — symmetric min/max for weights, calibrated activation ranges. The
paper evaluates FP32 vs INT8 predictions (Fig 1(g)/(h)) and weight
histograms (Fig 1(i)); `python/tests/test_quantize.py` and the
`fig1_training` bench reproduce those comparisons on the synthetic data.

The rust serving path mirrors this arithmetic in `rust/src/quant` so frames
can be quantized without python at runtime.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .kernels.ref import fake_quant_ref


def quantize_weights(params: dict, bits: int = 8) -> tuple[dict, dict]:
    """Symmetric per-tensor fake-quant of every weight tensor on a
    ``bits``-wide grid (INT8 by default — the TensorRT recipe).
    Returns (quantized params, {layer: scale})."""
    qmax = (1 << (bits - 1)) - 1
    out = {}
    scales = {}
    for name, p in params.items():
        absmax = float(jnp.max(jnp.abs(p["w"])))
        scale = max(absmax / qmax, 1e-12)
        wq = fake_quant_ref(p["w"], scale, 0, -qmax, qmax)
        out[name] = {"w": wq, "b": p["b"]}  # biases stay FP32 (TensorRT)
        scales[name] = scale
    return out, scales


def calibrate_input(frames: np.ndarray, bits: int = 8) -> tuple[float, int]:
    """Asymmetric unsigned activation calibration over a batch of frames.
    The grid and the zero-point clamp derive from the same ``bits``
    (mirrors ``rust/src/quant::QParams::calibrate_bits``)."""
    qmax = (1 << bits) - 1
    lo = min(float(frames.min()), 0.0)
    hi = max(float(frames.max()), 0.0)
    scale = max((hi - lo) / qmax, 1e-12)
    zero = min(max(int(round(-lo / scale)), 0), qmax)
    return scale, zero


def quantize_input(frames, scale, zero, bits: int = 8):
    qmax = (1 << bits) - 1
    q = jnp.round(frames / scale) + zero
    return (jnp.clip(q, 0, qmax) - zero) * scale


def weight_histogram(params: dict, bins: int = 101):
    """Pooled weight histogram (Fig 1(i)): returns (edges, counts)."""
    allw = np.concatenate([np.asarray(p["w"]).ravel() for p in params.values()])
    lo, hi = float(allw.min()), float(allw.max())
    counts, edges = np.histogram(allw, bins=bins, range=(lo, hi))
    return edges, counts


def distinct_levels(params: dict) -> int:
    """Distinct weight values — quantized nets collapse to ≤255 levels per
    tensor ('discrete levels', Fig 1(i))."""
    return int(
        max(
            len(np.unique(np.asarray(p["w"]))) for p in params.values()
        )
    )


def int8_eval_detnet(spec, params, params_q, frames, centers, radii):
    """FP32-vs-INT8 prediction comparison (Fig 1(g) analogue): returns the
    mean center error (normalized units) for both precisions."""
    x = jnp.asarray(frames)

    def center_err(p):
        logits = M.forward(spec, p, x, use_pallas=False)
        c, r, _ = M.detnet_outputs(logits)
        return float(jnp.mean(jnp.linalg.norm(c - centers, axis=-1)))

    return center_err(params), center_err(params_q)


def int8_eval_edsnet(spec, params, params_q, frames, masks):
    """FP32-vs-INT8 IoU comparison (Fig 1(h) analogue)."""
    x = jnp.asarray(frames)

    def miou(p):
        logits = M.forward(spec, p, x, use_pallas=False)
        pred = jnp.argmax(logits, axis=1)
        return M.iou(pred, jnp.asarray(masks))

    return miou(params), miou(params_q)
