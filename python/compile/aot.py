"""AOT compile path: lower the JAX models (with L1 Pallas kernels inlined
via interpret=True) to **HLO text** and export the workload JSON the rust
analytical models consume.

HLO *text*, not serialized HloModuleProto: jax ≥ 0.5 emits 64-bit
instruction ids that the `xla` crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids (see
/opt/xla-example/README.md).

Outputs per network under --out (default ../artifacts):
  <net>.hlo.txt        — the compiled inference function (batch 1)
  <net>.meta.json      — input shape + output names for rust/src/runtime
  <net>.workload.json  — layer list for rust/src/workload
Plus, if trained params exist (<net>.params.npz from compile.train), the
lowered function closes over them; otherwise over seeded random init.

Usage: cd python && python -m compile.aot [--out ../artifacts] [--net both]
       [--no-pallas]  (lower the pure-jnp path instead — ablation)
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def outputs_for(name: str):
    if name == "detnet":
        return ["centers", "radii", "label_logits"]
    return ["mask_logits"]


def build_fn(name, spec, params, use_pallas):
    if name == "detnet":

        def fn(x):
            logits = M.forward(spec, params, x, use_pallas=use_pallas)
            c, r, lab = M.detnet_outputs(logits)
            return (c, r, lab)

    else:

        def fn(x):
            logits = M.forward(spec, params, x, use_pallas=use_pallas)
            return (logits,)

    return fn


def export_net(name: str, out_dir: str, use_pallas: bool = True) -> str:
    spec = M.spec_by_name(name)

    params_path = os.path.join(out_dir, f"{name}.params.npz")
    if os.path.exists(params_path):
        from .train import load_params

        params = load_params(params_path)
        trained = True
    else:
        params = M.init_params(spec, jax.random.PRNGKey(0))
        trained = False

    c, h, w = spec.input
    x_spec = jax.ShapeDtypeStruct((1, c, h, w), jnp.float32)
    fn = build_fn(name, spec, params, use_pallas)
    lowered = jax.jit(fn).lower(x_spec)
    hlo = to_hlo_text(lowered)

    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)

    meta = dict(
        name=name,
        input_chw=[c, h, w],
        outputs=outputs_for(name),
        trained=trained,
        pallas=use_pallas,
    )
    with open(os.path.join(out_dir, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)

    with open(os.path.join(out_dir, f"{name}.workload.json"), "w") as f:
        json.dump(M.export_workload(spec), f, indent=1)

    return hlo_path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--net", choices=["detnet", "edsnet", "both"], default="both")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference path (ablation)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    nets = ["detnet", "edsnet"] if args.net == "both" else [args.net]
    for name in nets:
        path = export_net(name, args.out, use_pallas=not args.no_pallas)
        size = os.path.getsize(path)
        print(f"{name}: wrote {path} ({size/1e6:.1f} MB)")


if __name__ == "__main__":
    main()
