"""PTQ correctness (Fig 1(g)-(i) analogues): INT8 weights collapse to
discrete levels, quantized predictions stay close to FP32, calibration
round-trips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model as M, quantize as Q

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def det():
    spec = M.detnet_spec()
    params = M.init_params(spec, jax.random.PRNGKey(0))
    return spec, params


def test_quantized_weights_are_discrete(det):
    _, params = det
    params_q, scales = Q.quantize_weights(params)
    assert set(params_q) == set(params)
    for name, p in params_q.items():
        levels = len(np.unique(np.asarray(p["w"])))
        assert levels <= 255, f"{name}: {levels} levels"
        assert scales[name] > 0
        # max quantization error ≤ scale/2
        err = np.abs(np.asarray(p["w"]) - np.asarray(params[name]["w"])).max()
        assert err <= scales[name] / 2 + 1e-7


def test_biases_stay_fp32(det):
    _, params = det
    # give one bias many distinct values
    name = next(iter(params))
    params = dict(params)
    params[name] = {
        "w": params[name]["w"],
        "b": jnp.asarray(np.random.default_rng(0).random(params[name]["b"].shape, np.float32)),
    }
    params_q, _ = Q.quantize_weights(params)
    np.testing.assert_array_equal(params_q[name]["b"], params[name]["b"])


def test_int8_predictions_close_to_fp32(det):
    spec, params = det
    params_q, _ = Q.quantize_weights(params)
    rng = np.random.default_rng(1)
    frames, centers, radii, _ = data.hand_batch(4, rng)
    err_fp, err_q = Q.int8_eval_detnet(
        spec, params, params_q, frames, jnp.asarray(centers), jnp.asarray(radii)
    )
    # Untrained net: both errors are large but must be mutually close — the
    # INT8 degradation bound is what Fig 1(g) demonstrates qualitatively.
    assert abs(err_q - err_fp) < 0.15 * max(err_fp, 1e-6) + 0.02


def test_input_calibration_roundtrip():
    rng = np.random.default_rng(0)
    frames = rng.random((2, 1, 8, 8), dtype=np.float32)
    scale, zero = Q.calibrate_input(frames)
    q = Q.quantize_input(jnp.asarray(frames), scale, zero)
    assert float(jnp.max(jnp.abs(q - frames))) <= scale / 2 + 1e-7


def test_weight_histogram_mass(det):
    _, params = det
    edges, counts = Q.weight_histogram(params, bins=51)
    total = sum(int(np.asarray(p["w"]).size) for p in params.values())
    assert counts.sum() == total
    assert len(edges) == 52
    params_q, _ = Q.quantize_weights(params)
    assert Q.distinct_levels(params_q) <= 255
    assert Q.distinct_levels(params) > 255
