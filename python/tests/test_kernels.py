"""L1 correctness: Pallas kernels vs the pure-jnp oracle, swept over shapes
and strides with hypothesis. This is the CORE correctness signal for the
compile path — the AOT artifact embeds exactly these kernels."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as K
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=1e-4, atol=1e-4)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 160),
)
def test_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 31 + k * 7 + n)
    a, b = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(K.matmul(a, b), R.matmul_ref(a, b), **TOL)


def test_matmul_exact_tile_boundary():
    rng = np.random.default_rng(0)
    a, b = rand(rng, 128, 256), rand(rng, 256, 128)
    np.testing.assert_allclose(K.matmul(a, b), R.matmul_ref(a, b), **TOL)


def test_matmul_small_tile():
    rng = np.random.default_rng(1)
    a, b = rand(rng, 40, 50), rand(rng, 50, 30)
    np.testing.assert_allclose(K.matmul(a, b, tile=32), R.matmul_ref(a, b), **TOL)


# ---------------------------------------------------------------------------
# conv2d (im2col + pallas matmul)
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(1, 2),
    c=st.integers(1, 8),
    o=st.integers(1, 12),
    hw=st.integers(6, 24),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
)
def test_conv2d_matches_ref(n, c, o, hw, k, stride):
    rng = np.random.default_rng(n + c * 3 + o * 5 + hw * 7 + k * 11 + stride)
    x = rand(rng, n, c, hw, hw)
    w = rand(rng, o, c, k, k)
    pad = k // 2
    np.testing.assert_allclose(
        K.conv2d(x, w, stride, pad), R.conv2d_ref(x, w, stride, pad), **TOL
    )


def test_conv2d_rectangular_and_no_pad():
    rng = np.random.default_rng(5)
    x = rand(rng, 1, 3, 17, 29)
    w = rand(rng, 6, 3, 3, 3)
    np.testing.assert_allclose(K.conv2d(x, w, 1, 0), R.conv2d_ref(x, w, 1, 0), **TOL)


# ---------------------------------------------------------------------------
# depthwise conv
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    c=st.integers(1, 24),
    hw=st.integers(6, 20),
    stride=st.sampled_from([1, 2]),
    cb=st.sampled_from([1, 4, 8]),
)
def test_depthwise_matches_ref(c, hw, stride, cb):
    rng = np.random.default_rng(c * 13 + hw + stride + cb)
    x = rand(rng, 2, c, hw, hw)
    w = rand(rng, c, 1, 3, 3)
    np.testing.assert_allclose(
        K.depthwise_conv2d(x, w, stride, 1, c_block=cb),
        R.depthwise_conv2d_ref(x, w, stride, 1),
        **TOL,
    )


# ---------------------------------------------------------------------------
# fused IRB
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    c=st.integers(2, 10),
    e_mult=st.sampled_from([2, 4, 6]),
    o=st.integers(2, 10),
    hw=st.integers(6, 16),
    stride=st.sampled_from([1, 2]),
)
def test_irb_matches_ref(c, e_mult, o, hw, stride):
    rng = np.random.default_rng(c + e_mult + o * 3 + hw * 5 + stride)
    e = c * e_mult
    x = rand(rng, 1, c, hw, hw)
    we = rand(rng, e, c, 1, 1)
    wd = rand(rng, e, 1, 3, 3)
    wp = rand(rng, o, e, 1, 1)
    np.testing.assert_allclose(
        K.irb(x, we, wd, wp, stride), R.irb_ref(x, we, wd, wp, stride), **TOL
    )


def test_irb_residual_path_active():
    """When in_c == out_c and stride 1, the residual must be added."""
    rng = np.random.default_rng(9)
    c, e = 4, 16
    x = rand(rng, 1, c, 8, 8)
    we, wd = rand(rng, e, c, 1, 1), rand(rng, e, 1, 3, 3)
    wp = jnp.zeros((c, e, 1, 1), jnp.float32)  # projection outputs zero
    out = K.irb(x, we, wd, wp, 1)
    np.testing.assert_allclose(out, x, **TOL)  # residual passthrough


def test_fake_quant_ref_discretizes():
    x = jnp.linspace(-1, 1, 1001)
    q = R.fake_quant_ref(x, 1.0 / 127, 0)
    assert len(np.unique(np.asarray(q))) <= 255
    np.testing.assert_allclose(q, x, atol=1.0 / 127)
