"""Training-loop and AOT-export smoke tests (short budgets — the full runs
happen under `make train-curves` / `make artifacts`)."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model as M, train as T

jax.config.update("jax_platform_name", "cpu")


def test_detnet_training_reduces_circle_loss():
    # Fig 1(f) shape: the circle loss must drop substantially within a
    # short budget on the synthetic data.
    _, _, curve = T.train_detnet(steps=40, batch=8, seed=0, log_every=5)
    first, last = curve[0]["circle"], curve[-1]["circle"]
    assert last < 0.5 * first, f"{first} -> {last}"


@pytest.mark.slow
def test_edsnet_training_reduces_dice():
    _, _, curve = T.train_edsnet(steps=10, batch=2, seed=0, log_every=2)
    assert curve[-1]["dice"] < curve[0]["dice"]


def test_params_roundtrip(tmp_path):
    spec = M.detnet_spec()
    params = M.init_params(spec, jax.random.PRNGKey(0))
    path = tmp_path / "p.npz"
    T.save_params(params, path)
    loaded = T.load_params(path)
    assert set(loaded) == set(params)
    for name in params:
        np.testing.assert_array_equal(loaded[name]["w"], params[name]["w"])


def test_aot_export_detnet(tmp_path):
    path = aot.export_net("detnet", str(tmp_path), use_pallas=False)
    text = open(path).read()
    assert text.startswith("HloModule")
    meta = json.load(open(tmp_path / "detnet.meta.json"))
    assert meta["input_chw"] == [1, 128, 128]
    assert meta["outputs"] == ["centers", "radii", "label_logits"]
    wl = json.load(open(tmp_path / "detnet.workload.json"))
    assert wl["name"] == "detnet"
    assert len(wl["layers"]) > 20


def test_adamw_decays_weights():
    import jax.numpy as jnp

    params = {"l": {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}}
    grads = {"l": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}}
    state = T.adamw_init(params)
    p1, _ = T.adamw_step(params, grads, state, lr=0.1, wd=0.5)
    # zero gradient, nonzero weight decay → weights shrink
    assert float(p1["l"]["w"].mean()) < 1.0
    p2, _ = T.adamw_step(params, grads, state, lr=0.1, wd=0.0)
    np.testing.assert_allclose(p2["l"]["w"], 1.0)
