"""L2 correctness: model shapes, pallas-vs-ref forward agreement, loss
behaviour, and the workload-export contract with the rust side."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def det():
    spec = M.detnet_spec()
    params = M.init_params(spec, jax.random.PRNGKey(0))
    return spec, params


@pytest.fixture(scope="module")
def eds():
    spec = M.edsnet_spec()
    params = M.init_params(spec, jax.random.PRNGKey(1))
    return spec, params


def test_detnet_output_shape(det):
    spec, params = det
    x = jnp.zeros((2, 1, 128, 128))
    y = M.forward(spec, params, x, use_pallas=False)
    assert y.shape == (2, 8)


def test_edsnet_output_shape(eds):
    spec, params = eds
    x = jnp.zeros((1, 1, 192, 320))
    y = M.forward(spec, params, x, use_pallas=False)
    assert y.shape == (1, 4, 192, 320)


def test_detnet_pallas_matches_ref(det):
    spec, params = det
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.random((1, 1, 128, 128), dtype=np.float32))
    y_ref = M.forward(spec, params, x, use_pallas=False)
    y_pl = M.forward(spec, params, x, use_pallas=True)
    np.testing.assert_allclose(y_pl, y_ref, rtol=1e-4, atol=1e-4)


def test_macs_match_rust_builtin_anchors(det, eds):
    """The rust built-ins must agree; these anchors are asserted on both
    sides (rust: workload::builtin tests; integration: test_workload_json)."""
    d_macs = M.total_macs(det[0])
    e_macs = M.total_macs(eds[0])
    assert 5e6 < d_macs < 1e8
    ratio = e_macs / d_macs
    assert 20 < ratio < 500, ratio


def test_workload_export_schema(det):
    j = M.export_workload(det[0])
    assert j["name"] == "detnet"
    assert j["input"] == [1, 128, 128]
    for l in j["layers"]:
        for key in ("name", "kind", "in_c", "in_h", "in_w", "out_c", "out_h", "out_w"):
            assert key in l, l
        if l["kind"] in ("conv", "dwconv"):
            assert {"kh", "kw", "stride", "pad", "groups"} <= set(l)
        assert "src" not in l and "tap" not in l  # control flow stripped


def test_weights_fit_gwb(det, eds):
    """No DRAM: both models must fit the 512 kB global weight buffer at
    INT8 (arch invariant shared with rust)."""
    for spec in (det[0], eds[0]):
        assert M.total_weights(spec) <= 512 * 1024, spec.name


def test_residual_sources_resolved(det):
    adds = [l for l in det[0].layers if l["kind"] == "add"]
    assert adds, "detnet must have residual blocks"
    for l in adds:
        assert "src" in l
        src = det[0].layers[l["src"]]
        # residual operand is the *input* of the block's first layer: its
        # in_c/in_h/in_w must equal the add's output shape
        assert (src["in_c"], src["in_h"], src["in_w"]) == (
            l["out_c"], l["out_h"], l["out_w"],
        )


def test_detnet_loss_decreases_on_easy_batch(det):
    """One gradient step on a fixed batch must reduce the loss (training
    machinery sanity; the full curve is produced by compile.train)."""
    from compile.train import adamw_init, adamw_step

    spec, params = det
    rng = np.random.default_rng(3)
    frames, centers, radii, labels = data.hand_batch(8, rng)
    x, c, r, y = map(jnp.asarray, (frames, centers, radii, labels))

    def loss_fn(p):
        logits = M.forward(spec, p, x, use_pallas=False)
        circle, ce = M.detnet_loss(logits, c, r, y)
        return circle + 0.1 * ce

    state = adamw_init(params)
    l0, grads = jax.value_and_grad(loss_fn)(params)
    p1, state = adamw_step(params, grads, state, lr=1e-3)
    l1 = loss_fn(p1)
    assert float(l1) < float(l0)


def test_dice_loss_bounds(eds):
    spec, _ = eds
    n, c, h, w = 1, 4, 8, 8
    onehot = jnp.zeros((n, c, h, w)).at[:, 0].set(1.0)
    perfect = onehot * 1e3  # logits strongly favoring the right class
    assert float(M.dice_loss(perfect, onehot)) < 0.05
    # fully-wrong prediction: the two *present* classes score 0, the two
    # absent classes score 1 under the smoothed convention → loss 0.5
    wrong = jnp.roll(onehot, 1, axis=1) * 1e3
    assert float(M.dice_loss(wrong, onehot)) > 0.45


def test_iou_perfect_and_disjoint():
    a = jnp.array([[0, 1], [2, 3]])
    assert M.iou(a, a) == 1.0
    b = jnp.array([[1, 0], [3, 2]])
    assert M.iou(a, b) < 0.5


def test_hand_batch_statistics():
    rng = np.random.default_rng(0)
    frames, centers, radii, labels = data.hand_batch(16, rng)
    assert frames.shape == (16, 1, 128, 128)
    assert frames.min() >= 0.0 and frames.max() <= 1.0
    assert np.all((centers > 0) & (centers < 1))
    assert np.all(labels.sum(axis=1) == 1.0)


def test_eye_batch_statistics():
    rng = np.random.default_rng(0)
    frames, masks = data.eye_batch(4, rng)
    assert frames.shape == (4, 1, 192, 320)
    assert set(np.unique(masks)) <= {0, 1, 2, 3}
    # pupil (3) must exist and sit inside iris (2)
    assert (masks == 3).any() and (masks == 2).any()
    onehot = data.onehot_mask(masks)
    assert onehot.shape == (4, 4, 192, 320)
    np.testing.assert_allclose(onehot.sum(axis=1), 1.0)
