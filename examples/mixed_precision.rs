//! **Mixed-precision workload modeling demo** — the bit-width axis end to
//! end, offline and deterministic (CI runs this; it doubles as the ISSUE-5
//! acceptance gate):
//!
//! 1. *Identity*: an explicit INT8 [`PrecisionPolicy`] reproduces the
//!    default evaluation **bitwise** (every precision effect is a
//!    multiplication by `bits / datum_bits`, exactly 1.0 at INT8).
//! 2. *Query axis*: one query sweeps DetNet on Simba-v2 @7 nm across
//!    INT4 / INT8 / FP16 plus a hand-mixed per-layer schedule; energy,
//!    memory power and the quantized weight footprint are monotone
//!    nonincreasing in bit-width.
//! 3. *Search*: `xr-edge-dse search`-equivalent guided search over
//!    [`KnobSpace::paper_mixed_precision`] (the `--mixed-precision` CLI
//!    space) at 7 nm / ≥10 IPS, hill-climbing from the INT8 paper point —
//!    the best design found must be genuinely mixed-precision (non-INT8
//!    bits) and **strictly beat the best all-INT8 fixed-grid point** on
//!    energy per inference.
//!
//! Run: `cargo run --release --example mixed_precision`

use xr_edge_dse::arch::{self, MemFlavor, PeConfig};
use xr_edge_dse::dse::paper_sweeper;
use xr_edge_dse::eval::{Assignments, Devices, Engine, Query};
use xr_edge_dse::search::{
    ArchSynth, Constraints, Family, HillClimb, KnobSpace, Objective, SearchConfig, SearchReport,
    Strategy,
};
use xr_edge_dse::tech::{Device, Node};
use xr_edge_dse::workload::{builtin, LayerBits, PrecisionPolicy};

fn main() -> anyhow::Result<()> {
    // ---- act 1: INT8 is the identity, bitwise ---------------------------
    let default_pt = paper_sweeper()?
        .point("simba_v2", "detnet", Node::N7, MemFlavor::P1, Device::VgsotMram)
        .expect("paper grid point");
    let int8_engine = Engine::new(
        vec![arch::simba(PeConfig::V2)],
        vec![builtin::by_name("detnet")?.with_precision(PrecisionPolicy::int8())],
    );
    let explicit_pt = int8_engine
        .point("simba_v2", "detnet", Node::N7, MemFlavor::P1, Device::VgsotMram)
        .expect("explicit-policy point");
    anyhow::ensure!(
        default_pt.energy.total_pj().to_bits() == explicit_pt.energy.total_pj().to_bits()
            && default_pt.latency_ns.to_bits() == explicit_pt.latency_ns.to_bits()
            && default_pt.p_mem_uw(10.0).to_bits() == explicit_pt.p_mem_uw(10.0).to_bits(),
        "explicit INT8 policy diverged from the default path"
    );
    println!(
        "INT8 identity holds bitwise: simba_v2/P1@7nm = {:.2} µJ/inf either way ✓",
        default_pt.energy.total_pj() * 1e-6
    );

    // ---- act 2: the precision axis of the query surface -----------------
    let det = builtin::by_name("detnet")?;
    // Hand-mixed schedule: keep the stem at 8 bits, quantize everything
    // else to 4 (a classic accuracy-preserving XR-NPE-style split).
    let mut mixed = PrecisionPolicy::uniform("mixed", 4);
    if let Some(first) = det.layers.first() {
        mixed = mixed.with_layer(&first.name, LayerBits::INT8);
    }
    let engine = Engine::new(vec![arch::simba(PeConfig::V2)], vec![det.clone()]);
    let policies = [
        PrecisionPolicy::int4(),
        mixed,
        PrecisionPolicy::int8(),
        PrecisionPolicy::fp16(),
    ];
    let pts = Query::over(&engine)
        .nodes(&[Node::N7])
        .devices(Devices::Fixed(Device::VgsotMram))
        .assignments(Assignments::Flavors(vec![MemFlavor::P1]))
        .precisions(&policies)
        .points();
    anyhow::ensure!(pts.len() == policies.len(), "one point per policy");
    println!("\nDetNet on simba_v2 @7nm P1 (VGSOT), by precision policy:");
    for p in &pts {
        let qnet = det.clone().with_precision(
            policies.iter().find(|q| q.name() == p.precision).unwrap().clone(),
        );
        println!(
            "  {:<6} energy {:>8.2} µJ/inf   P_mem@10IPS {:>9.2} µW   weights {:>7} B   peak act {:>7} B",
            p.precision,
            p.energy.total_pj() * 1e-6,
            p.p_mem_uw(10.0),
            qnet.quantized_weight_bytes(),
            qnet.quantized_peak_activation_bytes()
        );
    }
    // monotone: int4 ≤ mixed ≤ int8 ≤ fp16 on energy
    for pair in pts.windows(2) {
        anyhow::ensure!(
            pair[0].energy.total_pj() <= pair[1].energy.total_pj(),
            "energy must be monotone nonincreasing in bit-width ({} vs {})",
            pair[0].precision,
            pair[1].precision
        );
    }
    println!("monotone in bit-width (energy): int4 ≤ mixed ≤ int8 ≤ fp16 ✓");

    // ---- act 3: mixed-precision guided search ---------------------------
    // The ISSUE-5 acceptance gate: with the bit-width knobs enabled (the
    // `--mixed-precision` space), the search must find a feasible design
    // at 7 nm / ≥10 IPS that is mixed-precision and strictly beats the
    // best all-INT8 fixed-grid paper point on energy.
    let mut space = KnobSpace::paper_mixed_precision();
    space.nodes = vec![Node::N7];
    let synth = ArchSynth::new(space, det)?;
    let cfg = SearchConfig {
        objective: Objective::Energy,
        constraints: Constraints::at_ips(10.0),
        budget: 600,
        batch: 32,
        seed: 42,
    };
    let seed_vec = synth
        .space
        .paper_vector(
            Family::WeightStationary,
            PeConfig::V2,
            MemFlavor::SramOnly,
            Node::N7,
            Device::VgsotMram,
        )
        .expect("INT8 paper point lives in the mixed space");
    let strategies: Vec<Box<dyn Strategy>> = vec![Box::new(HillClimb::seeded(seed_vec))];
    let report = SearchReport::run(&synth, &cfg, strategies);
    print!("\n{}", report.table().render());

    let (base_label, base_scalar, _) =
        report.baseline.as_ref().expect("the 7nm paper grid has feasible INT8 points");
    let (_, best) = report.best_overall().expect("search found a feasible design");
    anyhow::ensure!(
        best.scalar < *base_scalar,
        "search did not beat the all-INT8 grid: {} vs {base_scalar}",
        best.scalar
    );
    anyhow::ensure!(
        (best.w_bits, best.a_bits) != (8, 8),
        "best design must be mixed-precision, got w{}a{}",
        best.w_bits,
        best.a_bits
    );
    println!(
        "mixed-precision search beat the all-INT8 grid: {} {} {} — {:.2} µJ/inf vs {:.2} µJ/inf \
         for {} ({:.1}% less); knobs {} replay with seed {}",
        best.arch,
        best.assign,
        best.precision_label(),
        best.scalar * 1e-6,
        base_scalar * 1e-6,
        base_label,
        (1.0 - best.scalar / base_scalar) * 100.0,
        best.vector_key(),
        cfg.seed
    );
    Ok(())
}
