//! **Guided design-space search demo** — the layer that goes beyond the
//! paper's fixed grid. Three acts, all offline and deterministic (CI runs
//! this with the tiny default budget):
//!
//! 1. *Recover* a paper design point: the knob vector of Simba-v2/P1 at
//!    7 nm lowers through `search::ArchSynth` into an architecture that
//!    evaluates **bitwise-identically** to the fixed-grid engine path.
//! 2. *Engineer* an off-grid design by hand: the same datapath with
//!    right-sized global buffers (smallest GLB/GWB that still hold the
//!    workload) — strictly less energy per inference, by the CACTI-lite
//!    capacity monotonicity the property tests pin.
//! 3. *Search*: hill climbers seeded at both paper-v2 points, plus random
//!    sampling and simulated annealing, under a ≥10 IPS constraint —
//!    the report names each strategy's best design and its delta vs the
//!    best fixed-grid paper point (negative = the search won).
//!
//! Run: `cargo run --release --example search`

use xr_edge_dse::arch::{MemFlavor, PeConfig};
use xr_edge_dse::dse;
use xr_edge_dse::eval::Engine;
use xr_edge_dse::manifest::{exec, SearchSpec, SpaceBase, SpaceSpec};
use xr_edge_dse::search::{
    Annealing, ArchSynth, Family, HillClimb, RandomSearch, SearchReport, Strategy,
};
use xr_edge_dse::tech::{Device, Node};

fn main() -> anyhow::Result<()> {
    // CI artifact hook: XR_DSE_TRACE / XR_DSE_METRICS turn on the
    // observability journal for this run (flushed at the bottom).
    xr_edge_dse::obs::enable_from_env();
    // The experiment, declared through the same ExperimentSpec surface the
    // manifest binder and the CLI flags produce: the paper knob space
    // pinned to 7 nm, energy objective under a ≥10 IPS constraint (both
    // defaults), a CI-sized budget. `exec::build_search` lowers it onto
    // the synthesizer + config pair — identically to a `.xrdse` run.
    let spec = SearchSpec {
        space: SpaceSpec {
            base: Some(SpaceBase::Paper),
            nodes: Some(vec![Node::N7]),
            ..SpaceSpec::default()
        },
        budget: 120,
        batch: 32,
        ..SearchSpec::default()
    };
    let (synth, cfg) = exec::build_search(&spec)?;
    println!(
        "space: {} knob vectors; floors: GWB ≥ {} B (whole INT8 model), GLB ≥ {} B",
        synth.space.cardinality(),
        synth.net.weight_bytes(8),
        synth.min_glb_bytes()
    );

    // ---- act 1: recover the paper point, bitwise ------------------------
    let v2_p1 = synth
        .space
        .paper_vector(
            Family::WeightStationary,
            PeConfig::V2,
            MemFlavor::P1,
            Node::N7,
            Device::VgsotMram,
        )
        .expect("paper point is a member of the paper space");
    let cand = synth.lower(&v2_p1)?;
    let engine = Engine::new(vec![cand.arch.clone()], vec![synth.net.clone()]);
    let synth_pt = engine.eval_coords(&[(0, cand.node, cand.spec, cand.mram)]).remove(0);
    let grid_pt = dse::paper_sweeper()?
        .point("simba_v2", "detnet", Node::N7, MemFlavor::P1, Device::VgsotMram)
        .expect("paper grid point");
    anyhow::ensure!(
        synth_pt.energy.total_pj().to_bits() == grid_pt.energy.total_pj().to_bits()
            && synth_pt.latency_ns.to_bits() == grid_pt.latency_ns.to_bits()
            && synth_pt.area_mm2.to_bits() == grid_pt.area_mm2.to_bits(),
        "synthesized paper-v2 point diverged from the engine path"
    );
    println!(
        "recovered simba_v2/P1@7nm bitwise: {:.2} µJ/inf, {:.3} ms, {:.2} mm² ✓",
        synth_pt.energy.total_pj() * 1e-6,
        synth_pt.latency_ns / 1e6,
        synth_pt.area_mm2
    );

    // ---- act 2: an engineered off-grid design ---------------------------
    let ws_sram = synth
        .space
        .paper_vector(
            Family::WeightStationary,
            PeConfig::V2,
            MemFlavor::SramOnly,
            Node::N7,
            Device::VgsotMram,
        )
        .expect("paper point is a member of the paper space");
    let paper_energy = eval_energy(&synth, &ws_sram)?;
    let mut engineered = ws_sram.clone();
    engineered[5] = synth
        .space
        .glb_bytes
        .iter()
        .position(|&b| b as u64 >= synth.min_glb_bytes())
        .expect("GLB axis has a valid choice");
    engineered[7] = synth
        .space
        .gwb_bytes
        .iter()
        .position(|&b| b as u64 >= synth.net.weight_bytes(8))
        .expect("GWB axis has a valid choice");
    let engineered_energy = eval_energy(&synth, &engineered)?;
    anyhow::ensure!(
        engineered_energy < paper_energy,
        "right-sized buffers must cost strictly less energy ({engineered_energy} vs {paper_energy})"
    );
    println!(
        "off-grid: shrinking GLB {} → {} B and GWB {} → {} B saves {:.1}% energy/inf",
        synth.space.glb_bytes[ws_sram[5]],
        synth.space.glb_bytes[engineered[5]],
        synth.space.gwb_bytes[ws_sram[7]],
        synth.space.gwb_bytes[engineered[7]],
        (1.0 - engineered_energy / paper_energy) * 100.0
    );

    // ---- act 3: the guided search -------------------------------------
    let rs_sram = synth
        .space
        .paper_vector(
            Family::RowStationary,
            PeConfig::V2,
            MemFlavor::SramOnly,
            Node::N7,
            Device::VgsotMram,
        )
        .expect("paper point is a member of the paper space");
    let strategies: Vec<Box<dyn Strategy>> = vec![
        Box::new(HillClimb::seeded(ws_sram)),
        Box::new(HillClimb::seeded(rs_sram)),
        Box::new(RandomSearch),
        Box::new(Annealing::new()),
    ];
    let report = SearchReport::run(&synth, &cfg, strategies);
    print!("{}", report.table().render());

    // The acceptance gate this example doubles as in CI: the search found
    // a feasible 7 nm design with *strictly lower* energy/inference than
    // the best fixed-grid paper point under the same IPS constraint.
    let (base_label, base_scalar, _) =
        report.baseline.as_ref().expect("the 7nm paper grid has feasible points");
    let (winner, best) = report.best_overall().expect("search found a feasible design");
    anyhow::ensure!(
        best.scalar < *base_scalar,
        "search did not beat the fixed grid: {} vs {base_scalar}",
        best.scalar
    );
    println!(
        "search beat the fixed grid: {} {} via {} — {:.2} µJ/inf vs {:.2} µJ/inf for {} ({:.1}% less)\n\
         knob vector {} replays with seed {}; frontier sizes: {}",
        best.arch,
        best.assign,
        winner.strategy,
        best.scalar * 1e-6,
        base_scalar * 1e-6,
        base_label,
        (1.0 - best.scalar / base_scalar) * 100.0,
        best.vector_key(),
        cfg.seed,
        report
            .results
            .iter()
            .map(|r| format!("{} {}", r.strategy, r.frontier.len()))
            .collect::<Vec<_>>()
            .join(", ")
    );
    xr_edge_dse::obs::write_if_requested()?;
    Ok(())
}

/// Evaluate one knob vector's total energy per inference, pJ.
fn eval_energy(synth: &ArchSynth, v: &[usize]) -> anyhow::Result<f64> {
    let cand = synth.lower(&v.to_vec())?;
    let engine = Engine::new(vec![cand.arch.clone()], vec![synth.net.clone()]);
    let p = engine.eval_coords(&[(0, cand.node, cand.spec, cand.mram)]).remove(0);
    Ok(p.energy.total_pj())
}
