//! Domain example: design-space exploration for the eye-segmentation
//! workload — which (architecture × node × memory flavor) meets the
//! application's IPS_min at the lowest memory power, and what does it cost
//! in area? This is the §5 decision procedure ("one needs to carefully
//! fine-tune the proportion of the splits between NVM and SRAM") run as a
//! program.
//!
//! Run: `cargo run --release --example eye_segmentation_dse`

use xr_edge_dse::arch::{eyeriss, simba, MemFlavor, PeConfig};
use xr_edge_dse::mapping::map_network;
use xr_edge_dse::pipeline::meets_ips;
use xr_edge_dse::power::{power_model, savings_at};
use xr_edge_dse::report::{pct, Table};
use xr_edge_dse::tech::{paper_mram_for, Node};
use xr_edge_dse::workload::builtin;

fn main() -> anyhow::Result<()> {
    let net = builtin::by_name("edsnet")?;
    let ips_min = 0.1; // Table 3: eye segmentation
    println!(
        "DSE for {} ({:.0}M MACs) at IPS_min = {ips_min}\n",
        net.name,
        net.true_macs() as f64 / 1e6
    );

    let mut t = Table::new(
        "eye-segmentation design space @ IPS_min",
        &["arch", "node", "flavor", "feasible", "P_mem (µW)", "vs SRAM", "latency (ms)", "area (mm²)"],
    );
    let mut best: Option<(f64, String)> = None;
    for arch in [simba(PeConfig::V2), eyeriss(PeConfig::V2)] {
        let map = map_network(&arch, &net);
        for node in [Node::N28, Node::N7] {
            let mram = paper_mram_for(node);
            let sram = power_model(&arch, &map, node, MemFlavor::SramOnly, mram);
            for flavor in MemFlavor::ALL {
                let pm = power_model(&arch, &map, node, flavor, mram);
                let feasible = meets_ips(&pm, ips_min);
                let p = pm.p_mem_uw(ips_min);
                let a = xr_edge_dse::area::estimate(&arch, node, flavor, mram).total_mm2();
                t.row(vec![
                    arch.name.clone(),
                    node.label(),
                    flavor.label().into(),
                    if feasible { "yes" } else { "NO" }.into(),
                    format!("{p:.1}"),
                    pct(savings_at(&sram, &pm, ips_min)),
                    format!("{:.2}", pm.latency_ns / 1e6),
                    format!("{a:.2}"),
                ]);
                let key = format!("{} @{} {}", arch.name, node.label(), flavor.label());
                if feasible && best.as_ref().map(|(bp, _)| p < *bp).unwrap_or(true) {
                    best = Some((p, key));
                }
            }
        }
    }
    print!("{}", t.render());
    if let Some((p, key)) = best {
        println!("\nlowest-memory-power feasible design: {key} at {p:.1} µW");
    }

    // Pareto frontier over (P_mem, area, latency) at 7 nm — the undominated
    // designs a team would actually shortlist.
    {
        use xr_edge_dse::dse::{paper_sweeper, pareto};
        let s = paper_sweeper()?;
        let pts: Vec<_> = xr_edge_dse::dse::fig3d_grid(&s)
            .into_iter()
            .filter(|p| p.network == "edsnet" && p.node == Node::N7 && p.arch != "cpu")
            .collect();
        let front = pareto::frontier(&pts, ips_min);
        println!("\nPareto-optimal variants (P_mem @{ips_min} IPS, area, latency):");
        for &i in &front {
            let o = pareto::objectives(&pts[i], ips_min);
            println!(
                "  {} {:10} P_mem {:6.1} µW  area {:.2} mm²  latency {:.1} ms",
                pts[i].arch,
                pts[i].flavor.label(),
                o.p_mem_uw,
                o.area_mm2,
                o.latency_ms
            );
        }
    }
    println!(
        "\npaper cross-check (Table 3 @7nm): Simba saves with P0/P1; Eyeriss's\n\
         per-MAC weight-spad reads on read-penalized VGSOT erode its savings —\n\
         the read-intensive EDSNet workload is where the reversal shows."
    );
    Ok(())
}
