//! Domain example: design-space exploration for the eye-segmentation
//! workload — which (architecture × node × memory flavor) meets the
//! application's IPS_min at the lowest memory power, and what does it cost
//! in area? This is the §5 decision procedure ("one needs to carefully
//! fine-tune the proportion of the splits between NVM and SRAM") run as a
//! program — expressed as one query with a vs-SRAM baseline, plus a
//! `.pareto(..)` stage for the shortlist.
//!
//! Run: `cargo run --release --example eye_segmentation_dse`

use xr_edge_dse::arch::{eyeriss, simba, MemFlavor, PeConfig};
use xr_edge_dse::eval::{Engine, Query};
use xr_edge_dse::pipeline::meets_ips;
use xr_edge_dse::report::{pct, Table};
use xr_edge_dse::tech::Node;
use xr_edge_dse::workload::builtin;

fn main() -> anyhow::Result<()> {
    let net = builtin::by_name("edsnet")?;
    let ips_min = 0.1; // Table 3: eye segmentation
    println!(
        "DSE for {} ({:.0}M MACs) at IPS_min = {ips_min}\n",
        net.name,
        net.true_macs() as f64 / 1e6
    );

    let engine = Engine::new(vec![simba(PeConfig::V2), eyeriss(PeConfig::V2)], vec![net]);

    let mut t = Table::new(
        "eye-segmentation design space @ IPS_min",
        &["arch", "node", "flavor", "feasible", "P_mem (µW)", "vs SRAM", "latency (ms)", "area (mm²)"],
    );
    let mut best: Option<(f64, String)> = None;
    // Devices default to the paper's per-node pick (STT @28nm, VGSOT @7nm).
    Query::over(&engine)
        .nodes(&[Node::N28, Node::N7])
        .baseline(|p| p.flavor() == Some(MemFlavor::SramOnly))
        .for_each(|row| {
            let p = &row.point;
            let feasible = meets_ips(&p.power, ips_min);
            let p_mem = p.p_mem_uw(ips_min);
            t.row(vec![
                p.arch.clone(),
                p.node.label(),
                p.flavor_label().into(),
                if feasible { "yes" } else { "NO" }.into(),
                format!("{p_mem:.1}"),
                pct(row.p_mem_saving(ips_min).expect("baseline attached")),
                format!("{:.2}", p.latency_ns / 1e6),
                format!("{:.2}", p.area_mm2),
            ]);
            let key = format!("{} @{} {}", p.arch, p.node.label(), p.flavor_label());
            if feasible && best.as_ref().map(|(bp, _)| p_mem < *bp).unwrap_or(true) {
                best = Some((p_mem, key));
            }
        });
    print!("{}", t.render());
    if let Some((p, key)) = best {
        println!("\nlowest-memory-power feasible design: {key} at {p:.1} µW");
    }

    // Pareto frontier over (P_mem, area, latency) at 7 nm — the undominated
    // designs a team would actually shortlist, straight from the query's
    // `.pareto(..)` stage.
    {
        let front = Query::over(&engine).nodes(&[Node::N7]).pareto(ips_min).points();
        println!("\nPareto-optimal variants (P_mem @{ips_min} IPS, area, latency):");
        for p in &front {
            println!(
                "  {} {:10} P_mem {:6.1} µW  area {:.2} mm²  latency {:.1} ms",
                p.arch,
                p.flavor_label(),
                p.p_mem_uw(ips_min),
                p.area_mm2,
                p.latency_ns / 1e6
            );
        }
    }
    println!(
        "\npaper cross-check (Table 3 @7nm): Simba saves with P0/P1; Eyeriss's\n\
         per-MAC weight-spad reads on read-penalized VGSOT erode its savings —\n\
         the read-intensive EDSNet workload is where the reversal shows."
    );
    Ok(())
}
