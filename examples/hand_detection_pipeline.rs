//! **End-to-end validation driver** (EXPERIMENTS.md §E2E): the full serving
//! stack on a realistic workload — a synthetic ego-view hand camera streams
//! frames at the paper's IPS_min (10), the rust coordinator batches them to
//! the PJRT-compiled DetNet (JAX+Pallas AOT artifact; python never runs
//! here), predictions are scored against the generator's ground truth, and
//! the power-gate controller charges the Table-3 energy model for every
//! wakeup/inference/idle interval so measured latency and modeled memory
//! power come out of one run.
//!
//! Run: `make artifacts && cargo run --release --example hand_detection_pipeline`

use std::time::{Duration, Instant};
use xr_edge_dse::arch::{simba, MemFlavor, PeConfig};
use xr_edge_dse::coordinator::{gating::GateController, sensor::Sensor, Config, Coordinator};
use xr_edge_dse::mapping::map_network;
use xr_edge_dse::power::power_model;
use xr_edge_dse::tech::{Device, Node};
use xr_edge_dse::workload::builtin;

fn main() -> anyhow::Result<()> {
    let fps = 10.0; // Table 3: IPS_min for hand detection
    let seconds = 6.0;

    // --- the modeled accelerator variants whose ledgers we track ---
    let net = builtin::by_name("detnet")?;
    let arch = simba(PeConfig::V2);
    let map = map_network(&arch, &net);
    let mut ledgers: Vec<(String, GateController)> = MemFlavor::ALL
        .iter()
        .map(|&f| {
            let pm = power_model(&arch, &map, Node::N7, f, Device::VgsotMram);
            (f.label().to_string(), GateController::new(pm))
        })
        .collect();

    // --- the real serving pipeline ---
    println!("loading DetNet artifact + compiling on PJRT CPU…");
    let coord = Coordinator::start(Config {
        artifacts_dir: "artifacts".into(),
        model: "detnet".into(),
        queue_depth: 4,
    })?;
    let mut cam = Sensor::hand_camera(fps, 42);

    let t0 = Instant::now();
    let mut submitted = 0u64;
    let mut truths: Vec<(u64, Vec<f32>)> = Vec::new();
    while t0.elapsed().as_secs_f64() < seconds {
        std::thread::sleep(Duration::from_secs_f64(cam.next_gap_s()));
        let frame = cam.capture();
        truths.push((frame.id, frame.truth.clone()));
        if coord.submit(frame) {
            submitted += 1;
        }
        // charge the modeled accelerators for the same event schedule
        let period_ns = 1e9 / fps;
        for (_, g) in ledgers.iter_mut() {
            let before = g.elapsed_ns;
            g.inference();
            g.idle((period_ns - (g.elapsed_ns - before)).max(0.0));
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // --- collect predictions and score them ---
    let mut n_scored = 0usize;
    let mut center_err_sum = 0.0f64;
    while let Ok(res) = coord.results(0).try_recv() {
        if let Some((_, truth)) = truths.iter().find(|(id, _)| *id == res.frame_id) {
            // outputs[0] = sigmoid centers (x,y for 2 hands); truth = cx,cy,r
            let c = &res.outputs[0];
            let (dx, dy) = (c[0] - truth[0], c[1] - truth[1]);
            center_err_sum += ((dx * dx + dy * dy) as f64).sqrt();
            n_scored += 1;
        }
    }
    let dropped = coord.dropped_frames();
    let stats = coord.shutdown()?;
    print!(
        "{}",
        stats.render(&format!("hand-detection e2e @{fps} fps (DetNet via PJRT)"), wall, dropped)
    );
    if n_scored > 0 {
        println!(
            "prediction center error (normalized): {:.3} over {} frames{}",
            center_err_sum / n_scored as f64,
            n_scored,
            if std::path::Path::new("artifacts/detnet.params.npz").exists() {
                " [trained params]"
            } else {
                " [untrained init — run `make train-curves` for a real model]"
            }
        );
    }

    println!("\nmodeled memory power at the observed schedule (Table-3 cross-check):");
    for (label, g) in &ledgers {
        println!(
            "  {label:9} {:8.1} µW  ({} inferences, {} wakeups, {:.1} IPS observed)",
            g.avg_power_uw(),
            g.inferences,
            g.wakeups,
            g.observed_ips()
        );
    }
    println!("\nsubmitted {submitted} frames; see EXPERIMENTS.md §E2E for the recorded run");
    Ok(())
}
