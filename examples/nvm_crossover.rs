//! Sensitivity analysis on the Fig-5 crossover points: sweep the three
//! calibration knobs (`tech::knobs`) around their defaults by re-invoking
//! this binary with the env overrides, and print the cut-off IPS for every
//! (arch × workload × flavor × device) cell — the quantity Fig 5 annotates.
//!
//! Run: `cargo run --release --example nvm_crossover`
//! Sweep: `XR_DSE_VGSOT_READ_MULT=2.0 cargo run --release --example nvm_crossover`

use xr_edge_dse::arch::{eyeriss, simba, MemFlavor, PeConfig};
use xr_edge_dse::mapping::map_network;
use xr_edge_dse::power::{crossover_ips, power_model};
use xr_edge_dse::report::Table;
use xr_edge_dse::tech::{knobs, Device, Node};
use xr_edge_dse::workload::builtin;

fn main() -> anyhow::Result<()> {
    let k = knobs();
    println!(
        "knobs: retention {} µW/KB, wakeup {} pJ/B, VGSOT read ×{}\n",
        k.ret_uw_per_kb_7nm, k.wakeup_pj_per_byte_7nm, k.vgsot_read_mult
    );

    let mut t = Table::new(
        "Fig 5 — cut-off IPS (NVM wins below; '∞' = wins up to its max rate; '-' = never)",
        &["arch", "workload", "flavor", "STT", "SOT", "VGSOT", "max IPS"],
    );
    for arch in [simba(PeConfig::V2), eyeriss(PeConfig::V2)] {
        for net_name in ["detnet", "edsnet"] {
            let net = builtin::by_name(net_name)?;
            let map = map_network(&arch, &net);
            for flavor in [MemFlavor::P1, MemFlavor::P0] {
                let mut cells = Vec::new();
                let mut max_ips = f64::INFINITY;
                for device in Device::MRAMS {
                    let sram = power_model(&arch, &map, Node::N7, MemFlavor::SramOnly, device);
                    let nvm = power_model(&arch, &map, Node::N7, flavor, device);
                    max_ips = nvm.max_ips();
                    cells.push(match crossover_ips(&sram, &nvm) {
                        Some(x) if (x - nvm.max_ips()).abs() < 1e-6 => "∞".to_string(),
                        Some(x) => format!("{x:.1}"),
                        None => "-".to_string(),
                    });
                }
                t.row(vec![
                    arch.name.clone(),
                    net_name.into(),
                    flavor.label().into(),
                    cells[0].clone(),
                    cells[1].clone(),
                    cells[2].clone(),
                    format!("{max_ips:.0}"),
                ]);
            }
        }
    }
    print!("{}", t.render());
    println!(
        "\npaper shape check: Simba P0 cut-offs sit above Eyeriss's with VGSOT\n\
         (§5: VGSOT 'improves for Simba whereas it decreases for Eyeriss'),\n\
         and every crossover above the workload's IPS_min (10 / 0.1) means\n\
         the NVM variant saves power in deployment."
    );
    Ok(())
}
