//! Sensitivity analysis on the Fig-5 crossover points: sweep the three
//! calibration knobs (`tech::Knobs`) around their defaults and print the
//! cut-off IPS for every (arch × workload × flavor × device) cell — the
//! quantity Fig 5 annotates.
//!
//! The grid is one query with an explicit MRAM-device axis
//! (`Devices::Each`) and the SRAM-only point of each (arch, net, device)
//! group attached as baseline, so every crossover comes from the row
//! itself.
//!
//! Knobs are an injectable value (`Engine::with_knobs`), so the VGSOT
//! read-penalty sweep at the end runs **in-process** — one engine per
//! knob setting, no env mutation, no stale `OnceLock` snapshot. The env
//! overrides (`XR_DSE_VGSOT_READ_MULT` etc.) still seed the defaults for
//! cross-process sweeps.
//!
//! Run: `cargo run --release --example nvm_crossover`
//! Seeded: `XR_DSE_VGSOT_READ_MULT=2.0 cargo run --release --example nvm_crossover`

use xr_edge_dse::arch::{eyeriss, simba, MemFlavor, PeConfig};
use xr_edge_dse::eval::{Devices, Engine, Query};
use xr_edge_dse::power::crossover_ips;
use xr_edge_dse::report::Table;
use xr_edge_dse::tech::{knobs, Device, Knobs, Node};
use xr_edge_dse::workload::builtin;

fn main() -> anyhow::Result<()> {
    let k = knobs();
    println!(
        "knobs: retention {} µW/KB, wakeup {} pJ/B, VGSOT read ×{}\n",
        k.ret_uw_per_kb_7nm, k.wakeup_pj_per_byte_7nm, k.vgsot_read_mult
    );

    let engine = Engine::new(
        vec![simba(PeConfig::V2), eyeriss(PeConfig::V2)],
        vec![builtin::by_name("detnet")?, builtin::by_name("edsnet")?],
    );
    let rows = Query::over(&engine)
        .nodes(&[Node::N7])
        .devices(Devices::Each(Device::MRAMS.to_vec()))
        .baseline(|p| p.flavor() == Some(MemFlavor::SramOnly))
        .collect();

    let mut t = Table::new(
        "Fig 5 — cut-off IPS (NVM wins below; '∞' = wins up to its max rate; '-' = never)",
        &["arch", "workload", "flavor", "STT", "SOT", "VGSOT", "max IPS"],
    );
    // Rows arrive in canonical entry → device → flavor order, so every
    // cell is a direct index — no per-cell scan over the grid.
    let per_device = MemFlavor::ALL.len();
    let per_entry = Device::MRAMS.len() * per_device;
    for (ei, entry) in engine.entries().iter().enumerate() {
        for flavor in [MemFlavor::P1, MemFlavor::P0] {
            let fi = MemFlavor::ALL.iter().position(|&f| f == flavor).unwrap();
            let mut cells = Vec::new();
            let mut max_ips = f64::INFINITY;
            for di in 0..Device::MRAMS.len() {
                let row = &rows[ei * per_entry + di * per_device + fi];
                assert_eq!(row.point.flavor(), Some(flavor), "canonical order");
                let sram = &row.baseline.as_ref().expect("baseline attached").power;
                let nvm = &row.point.power;
                max_ips = nvm.max_ips();
                cells.push(match crossover_ips(sram, nvm) {
                    Some(x) if (x - nvm.max_ips()).abs() < 1e-6 => "∞".to_string(),
                    Some(x) => format!("{x:.1}"),
                    None => "-".to_string(),
                });
            }
            t.row(vec![
                entry.arch.name.clone(),
                entry.map.network.clone(),
                flavor.label().into(),
                cells[0].clone(),
                cells[1].clone(),
                cells[2].clone(),
                format!("{max_ips:.0}"),
            ]);
        }
    }
    print!("{}", t.render());
    println!(
        "\npaper shape check: Simba P0 cut-offs sit above Eyeriss's with VGSOT\n\
         (§5: VGSOT 'improves for Simba whereas it decreases for Eyeriss'),\n\
         and every crossover above the workload's IPS_min (10 / 0.1) means\n\
         the NVM variant saves power in deployment."
    );

    // In-process sensitivity sweep: one engine per knob value. Before
    // knobs were injectable this required re-invoking the binary — the
    // first model construction froze the env in a OnceLock.
    let mut sweep = Table::new(
        "VGSOT read-mult sweep (in-process) — simba_v2/detnet P1@7nm vs SRAM",
        &["×SRAM read", "E_mem P1 (µJ)", "E_mem SRAM (µJ)", "P1 cut-off IPS"],
    );
    let mut last_e = -1.0;
    for mult in [2.0, 3.2, 4.5] {
        let engine = Engine::new(vec![simba(PeConfig::V2)], vec![builtin::by_name("detnet")?])
            .with_knobs(Knobs { vgsot_read_mult: mult, ..k });
        let pts = Query::over(&engine)
            .nodes(&[Node::N7])
            .devices(Devices::Fixed(Device::VgsotMram))
            .collect();
        // canonical flavor order: SRAM-only, P0, P1
        let sram = &pts[0].point.power;
        let p1 = &pts[2].point.power;
        sweep.row(vec![
            format!("{mult:.1}"),
            format!("{:.3}", p1.e_mem_inf_pj * 1e-6),
            format!("{:.3}", sram.e_mem_inf_pj * 1e-6),
            match crossover_ips(sram, p1) {
                Some(x) => format!("{x:.1}"),
                None => "-".into(),
            },
        ]);
        assert!(
            p1.e_mem_inf_pj > last_e,
            "raising the read penalty must raise P1 memory energy in-process"
        );
        last_e = p1.e_mem_inf_pj;
    }
    print!("{}", sweep.render());
    Ok(())
}
