//! **Multi-stream scenario serving demo**: the paper's §5/Table-3
//! device-level operating point — one XR SoC concurrently running hand
//! detection (DetNet @ 10 IPS, hybrid P0 memory) and eye segmentation
//! (EDSNet @ 0.1 IPS, full-NVM P1) — replayed at 60× wall-clock
//! compression with a power-gate ledger per stream.
//!
//! Runs fully offline on the synthetic backend (no PJRT, no artifacts), so
//! CI exercises the whole serving layer: drop-oldest queues, per-stream
//! workers, ledger-vs-closed-form power agreement.
//!
//! Run: `cargo run --release --example scenario`

use xr_edge_dse::coordinator::Backend;

fn main() -> anyhow::Result<()> {
    // Presets are named manifests (`manifests/scenario_paper.xrdse`),
    // resolved through the manifest binder.
    let mut sc = xr_edge_dse::manifest::scenario_preset("paper", "artifacts".into())?;
    // Deterministic offline path; swap for Backend::Auto{..} to use PJRT
    // artifacts when `make artifacts` has been run.
    sc.backend = Backend::Synthetic;
    // This example doubles as a CI gate asserting zero drops, so give the
    // queues enough headroom that an OS scheduling stall on a loaded
    // runner can never evict a frame.
    for s in sc.streams.iter_mut() {
        s.queue_depth = 64;
    }
    let report = sc.run()?;
    print!("{}", report.table().render());
    println!("{}", report.summary_line());

    // The acceptance gate this example doubles as in CI: both streams
    // served frames, nothing was dropped at the paper rates, and each
    // stream's ledger reproduces the closed-form P_mem at its observed
    // IPS within 2%.
    anyhow::ensure!(report.streams.len() == 2, "paper preset is two streams");
    for s in &report.streams {
        anyhow::ensure!(s.served > 0, "stream '{}' served nothing", s.name);
        anyhow::ensure!(s.dropped == 0, "stream '{}' dropped {} frames", s.name, s.dropped);
        anyhow::ensure!(
            s.p_mem_rel_err() < 0.02,
            "stream '{}': ledger {:.3} µW vs closed-form {:.3} µW ({:.2}% off)",
            s.name,
            s.ledger_uw,
            s.closed_form_uw,
            s.p_mem_rel_err() * 100.0
        );
    }
    println!("ledger ↔ closed-form agreement within 2% on every stream ✓");
    Ok(())
}
