//! **Fleet orchestration demo** — the virtual-clock device-fleet
//! simulator, CI-run as the ISSUE-7 acceptance harness. Three acts, all
//! offline and deterministic:
//!
//! 1. *Deploy* a heterogeneous pool: the paper's §5 device menu
//!    (simba-v2 in all three memory flavors + eyeriss-v2 P1 at 7 nm)
//!    plus four off-grid designs lowered straight from a guided-search
//!    frontier — the PR-4/PR-6 search layer feeding the fleet.
//! 2. *Place and simulate* an XR stream mix (hand detnet @ 10 fps +
//!    eye edsnet Poisson @ 1/s) under each placement policy: every
//!    stream lands, accounting conserves frames, every per-stream
//!    power-gate ledger agrees with the closed form within 2%, and a
//!    rerun is bitwise-identical.
//! 3. *Constrain*: halve the fleet's aggregate power budget — placement
//!    must reject streams (visibly, in the report) while the placed
//!    remainder still simulates cleanly.
//!
//! Run: `cargo run --release --example fleet`

use xr_edge_dse::fleet::{policy_by_name, run_fleet, HwPoint};
use xr_edge_dse::manifest::{
    exec, ArrivalDecl, FleetPlan, LoadDecl, SearchSpec, SpaceBase, SpaceSpec,
};
use xr_edge_dse::search::{run_search, RandomSearch};
use xr_edge_dse::tech::{Device, Node};

fn main() -> anyhow::Result<()> {
    // CI artifact hook: XR_DSE_TRACE / XR_DSE_METRICS turn on the
    // observability journal for this run (flushed at the bottom).
    xr_edge_dse::obs::enable_from_env();
    // ---- act 1: the device pool ----------------------------------------
    // The fleet and the frontier search are both declared through the
    // ExperimentSpec surface (the same types a `.xrdse` manifest binds
    // to); `exec::build_fleet` / `exec::build_search` lower them onto the
    // fleet and search subsystems exactly as a manifest run would.
    let plan = FleetPlan {
        devices: 32,
        seconds: 60.0,
        node: Node::N7,
        mram: Device::VgsotMram,
        // Each stream owns its modeled server, so utilization is a
        // placement knob, not a physical limit; lift it so act 2
        // demonstrates full placement and act 3's rejections come from
        // the power cap alone.
        max_util: Some(1e6),
        ..FleetPlan::default()
    }
    .with_load(LoadDecl::new("hand", "detnet", ArrivalDecl::Periodic { fps: 10.0 }, 192))
    .with_load(LoadDecl::new("eye", "edsnet", ArrivalDecl::Poisson { rate: 1.0 }, 64));
    let mut spec = exec::build_fleet("xr-fleet", &plan)?;

    let search = SearchSpec {
        space: SpaceSpec {
            base: Some(SpaceBase::Paper),
            nodes: Some(vec![Node::N7]),
            ..SpaceSpec::default()
        },
        budget: 48,
        batch: 24,
        ..SearchSpec::default()
    };
    let (synth, cfg) = exec::build_search(&search)?;
    let result = run_search(&synth, &mut RandomSearch, &cfg);
    let frontier = HwPoint::from_frontier(&synth, &result, 4)?;
    println!(
        "device pool: {} paper points + {} frontier designs ({})",
        spec.points.len(),
        frontier.len(),
        frontier.iter().map(|p| p.name.as_str()).collect::<Vec<_>>().join(", ")
    );
    spec.points.extend(frontier);

    // ---- act 2: place + simulate under every policy --------------------

    let mut baseline_total_uw = 0.0;
    for name in ["round-robin", "least-loaded", "weighted-random"] {
        let mut policy = policy_by_name(name)?;
        let r = run_fleet(&spec, policy.as_mut())?;
        print!("{}", r.table().render());
        println!("{}\n", r.summary_line());
        anyhow::ensure!(
            r.placed == r.requested && r.rejections == 0,
            "[{name}] unconstrained fleet must place everything: {}/{} placed",
            r.placed,
            r.requested
        );
        anyhow::ensure!(r.served > 0, "[{name}] fleet served nothing");
        anyhow::ensure!(
            r.submitted == r.served + r.dropped,
            "[{name}] conservation broke: {} submitted vs {} served + {} dropped",
            r.submitted,
            r.served,
            r.dropped
        );
        anyhow::ensure!(
            r.worst_rel_err < 0.02,
            "[{name}] a stream's ledger diverged from closed form: {:.4}",
            r.worst_rel_err
        );
        baseline_total_uw = r.p_mem_uw;
    }

    // Determinism gate: one policy rerun from the same seed is bitwise-
    // identical on every modeled quantity the report aggregates.
    let a = run_fleet(&spec, policy_by_name("least-loaded")?.as_mut())?;
    let b = run_fleet(&spec, policy_by_name("least-loaded")?.as_mut())?;
    anyhow::ensure!(
        a.energy_pj.to_bits() == b.energy_pj.to_bits()
            && a.e2e.p99.to_bits() == b.e2e.p99.to_bits()
            && a.events == b.events,
        "fleet rerun was not bitwise-reproducible"
    );
    println!("least-loaded rerun bitwise-identical: {} events, {:.1} pJ total ✓", a.events, a.energy_pj);

    // ---- act 3: a power-capped fleet must reject visibly ---------------
    // Per-device cap at total/(2·devices): the whole fleet now holds half
    // the unconstrained load's power, so placement cannot admit everyone.
    let mut capped = spec.clone();
    capped.constraints.max_p_mem_uw = Some(baseline_total_uw / (2.0 * capped.n_devices as f64));
    let r = run_fleet(&capped, policy_by_name("weighted")?.as_mut())?;
    println!("{}", r.summary_line());
    anyhow::ensure!(
        r.rejections > 0 && r.placed > 0 && r.placed + r.rejections == r.requested,
        "capped fleet should place some and reject some: {} placed, {} rejected of {}",
        r.placed,
        r.rejections,
        r.requested
    );
    anyhow::ensure!(
        r.submitted == r.served + r.dropped,
        "capped conservation broke: {} vs {} + {}",
        r.submitted,
        r.served,
        r.dropped
    );
    println!(
        "power cap {:.2} µW/device: {} streams rejected, placed remainder still ledger-clean (worst Δ {:.3}%) ✓",
        capped.constraints.max_p_mem_uw.unwrap(),
        r.rejections,
        r.worst_rel_err * 100.0
    );
    xr_edge_dse::obs::write_if_requested()?;
    Ok(())
}
