//! Quickstart: evaluate one design point end-to-end with the analytical
//! stack — map a workload onto an accelerator, sweep the three memory
//! flavors with one query, and ask whether MRAM pays off at your frame
//! rate.
//!
//! Run: `cargo run --release --example quickstart`

use xr_edge_dse::arch::{simba, MemFlavor, PeConfig};
use xr_edge_dse::eval::{Devices, Engine, Query};
use xr_edge_dse::power::crossover_ips;
use xr_edge_dse::tech::{Device, Node};
use xr_edge_dse::util::units::format_si;
use xr_edge_dse::workload::builtin;

fn main() -> anyhow::Result<()> {
    // 1. A workload and an architecture, mapped once into an engine.
    let net = builtin::by_name("detnet")?;
    let arch = simba(PeConfig::V2);
    println!(
        "workload {}: {:.1}M MACs, {} weights",
        net.name,
        net.true_macs() as f64 / 1e6,
        xr_edge_dse::util::units::format_bytes(net.weight_bytes(8) as usize),
    );
    let engine = Engine::new(vec![arch.clone()], vec![net]);

    // 2. The cached mapping (Timeloop-lite ran once, at engine build).
    let map = &engine.entries()[0].map;
    println!(
        "mapped onto {}: {:.0} cycles, {:.1}% array utilization",
        arch.name,
        map.total_cycles(),
        map.utilization(&arch) * 100.0
    );

    // 3. Energy + latency + area at 7 nm for the three memory flavors —
    //    one query, with the SRAM-only point attached as baseline.
    let rows = Query::over(&engine)
        .nodes(&[Node::N7])
        .devices(Devices::Fixed(Device::VgsotMram))
        .baseline(|p| p.flavor() == Some(MemFlavor::SramOnly))
        .collect();
    for row in &rows {
        let p = &row.point;
        println!(
            "  {:9} energy {:>10}  latency {:>9}  area {:.2} mm²",
            p.flavor_label(),
            format_si(p.energy.total_pj() * 1e-12, "J"),
            format_si(p.latency_ns * 1e-9, "s"),
            p.area_mm2
        );
    }

    // 4. Should you use MRAM at 10 inferences/second? (Table 3's question.)
    let (sram, p1) = (&rows[0], &rows[2]);
    let ips = 10.0;
    println!(
        "\nat {ips} IPS: SRAM {:.1} µW vs P1 {:.1} µW → P1 saves {:.1}%",
        sram.point.p_mem_uw(ips),
        p1.point.p_mem_uw(ips),
        p1.p_mem_saving(ips).expect("baseline attached") * 100.0
    );
    if let Some(x) = crossover_ips(&sram.point.power, &p1.point.power) {
        println!("P1 wins below the cut-off of {x:.0} IPS");
    }
    Ok(())
}
