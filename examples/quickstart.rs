//! Quickstart: evaluate one design point end-to-end with the analytical
//! stack — map a workload onto an accelerator, estimate energy / latency /
//! area, and ask the power model whether MRAM pays off at your frame rate.
//!
//! Run: `cargo run --release --example quickstart`

use xr_edge_dse::arch::{simba, MemFlavor, PeConfig};
use xr_edge_dse::mapping::map_network;
use xr_edge_dse::power::{crossover_ips, power_model, savings_at};
use xr_edge_dse::tech::{Device, Node};
use xr_edge_dse::util::units::format_si;
use xr_edge_dse::workload::builtin;
use xr_edge_dse::{area, energy};

fn main() -> anyhow::Result<()> {
    // 1. A workload and an architecture.
    let net = builtin::by_name("detnet")?;
    let arch = simba(PeConfig::V2);
    println!(
        "workload {}: {:.1}M MACs, {} weights",
        net.name,
        net.true_macs() as f64 / 1e6,
        xr_edge_dse::util::units::format_bytes(net.weight_bytes(8) as usize),
    );

    // 2. Map it (Timeloop-lite).
    let map = map_network(&arch, &net);
    println!(
        "mapped onto {}: {:.0} cycles, {:.1}% array utilization",
        arch.name,
        map.total_cycles(),
        map.utilization(&arch) * 100.0
    );

    // 3. Energy + latency at 7 nm for the three memory flavors.
    let node = Node::N7;
    let mram = Device::VgsotMram;
    for flavor in MemFlavor::ALL {
        let e = energy::estimate(&arch, &map, node, flavor, mram);
        let lat = energy::latency_ns(&arch, &map, node, flavor, mram);
        let a = area::estimate(&arch, node, flavor, mram);
        println!(
            "  {:9} energy {:>10}  latency {:>9}  area {:.2} mm²",
            flavor.label(),
            format_si(e.total_pj() * 1e-12, "J"),
            format_si(lat * 1e-9, "s"),
            a.total_mm2()
        );
    }

    // 4. Should you use MRAM at 10 inferences/second? (Table 3's question.)
    let sram = power_model(&arch, &map, node, MemFlavor::SramOnly, mram);
    let p1 = power_model(&arch, &map, node, MemFlavor::P1, mram);
    let ips = 10.0;
    println!(
        "\nat {ips} IPS: SRAM {:.1} µW vs P1 {:.1} µW → P1 saves {:.1}%",
        sram.p_mem_uw(ips),
        p1.p_mem_uw(ips),
        savings_at(&sram, &p1, ips) * 100.0
    );
    if let Some(x) = crossover_ips(&sram, &p1) {
        println!("P1 wins below the cut-off of {x:.0} IPS");
    }
    Ok(())
}
